//! The serving system, split into two typed planes:
//!
//! * **data plane** — [`Fleet::submit`]: the typed `Request`/`Ticket`
//!   path. A [`Scheduler`] picks the member per request, an
//!   [`AdmissionPolicy`] decides what a full queue means.
//! * **control plane** — [`FleetController`]: lifecycle and
//!   reconfiguration commands against a *live* fleet — add/remove/drain
//!   members, retune a member's tile after a tuning refresh, swap the
//!   scheduler/admission policy, tune the work-stealing knobs — all
//!   without restarting workers.
//!
//! Membership lives behind a versioned registry: an epoch-stamped
//! topology snapshot behind an `Arc<RwLock<Arc<_>>>` (the same
//! pattern as [`SharedRouter`]). Schedulers, batchers, and thieves read
//! the current snapshot per decision, so membership changes are
//! race-free by construction — a submit that raced a removal either sees
//! the old snapshot (and the drained member answers or hands the work to
//! the pipeline that is still flushing) or the new one.
//!
//! Build one with [`FleetBuilder`]:
//!
//! ```no_run
//! # use std::sync::Arc;
//! # use tilekit::config::ServingConfig;
//! # use tilekit::coordinator::{DrainMode, FleetBuilder, LeastLoaded, Request, TilePolicy};
//! # use tilekit::device::find_device;
//! # use tilekit::image::{generate, Interpolator};
//! # use tilekit::runtime::{Manifest, MockEngine};
//! # let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
//! # let outcome = tilekit::autotuner::TuningSession::sim().run()?;
//! let fleet = FleetBuilder::new(&ServingConfig::default(), &manifest)
//!     .device(
//!         find_device("gtx260").unwrap(),
//!         Arc::new(MockEngine::new()),
//!         TilePolicy::PerDevice(outcome.clone()),
//!     )
//!     .scheduler(LeastLoaded)
//!     .build()?;
//! let ticket = fleet.submit(Request::new(
//!     Interpolator::Bilinear,
//!     generate::gradient(64, 64),
//!     2,
//! ))?;
//! let _img = ticket.wait()?;
//! // Reconfigure the live fleet through its control plane:
//! let ctl = fleet.controller();
//! ctl.add_member(
//!     find_device("fermi").unwrap(),
//!     Arc::new(MockEngine::new()),
//!     TilePolicy::PerDevice(outcome),
//! )?;
//! ctl.remove_member("gtx260", DrainMode::Graceful)?;
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! `Service` and `ServiceBuilder` remain as **deprecated** aliases of
//! [`Fleet`] and [`FleetBuilder`]; they are the same types, so a
//! find/replace migrates existing callers.

use super::admission::{admission_by_name, AdmissionPolicy};
use super::batcher::{Batch, BatcherState, Shed};
use super::request::{Request, RequestKey, ResizeRequest, Ticket};
use super::router::{Router, SharedRouter, TilePolicy};
use super::scheduler::{scheduler_by_name, CostMeter, DeviceSnapshot, Scheduler};
use super::stats::{IdGen, ServingStats};
use super::stealing::{
    select_batch_migration, select_steals, StealPolicy, MIGRATE_MIN_LIVE,
};
use super::worker::spawn_workers;
use crate::autotuner::{CostModel, SimCostModel, TuningOutcome};
use crate::config::ServingConfig;
use crate::device::DeviceDescriptor;
use crate::exec::{bounded, Receiver, Sender};
use crate::metrics::Counter;
use crate::net::protocol::saturating_duration_from_ms;
use crate::runtime::{Manifest, ResizeBackend};
use crate::tiling::TileDim;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Cap on the batcher's poll interval while requests are pending, so
/// cancellations and expired deadlines are shed promptly even when the
/// batch deadline is long.
const SHED_POLL: Duration = Duration::from_millis(5);

/// Idle-poll interval of a batcher that may steal, used only while a
/// peer is actually over the steal threshold — a quiet fleet stays on
/// the slow 50ms idle tick.
const STEAL_POLL: Duration = Duration::from_millis(2);

/// Dynamic-batch cap for members with no device identity and no
/// explicit `batch_max` override (the classic single-backend default).
pub const ANON_BATCH_MAX: usize = 8;

/// Why a submission was not admitted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// Admission queue full (or the admission timeout elapsed) — retry
    /// later (backpressure).
    Saturated,
    /// No member's artifact set can serve this (kernel, size, scale).
    Unsupported,
    /// The request's latency budget is already spent.
    DeadlineExceeded,
    /// The deadline budget is below the best queue-depth-aware ETA any
    /// member offers: no device can meet it, so the service declines up
    /// front instead of accepting work it would shed later.
    Infeasible,
    /// Service is shutting down (or the scheduled member was removed
    /// while this submission was in flight — retry; the next snapshot
    /// routes around it).
    ShuttingDown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Saturated => write!(f, "admission queue saturated"),
            SubmitError::Unsupported => write!(f, "no device serves this request shape"),
            SubmitError::DeadlineExceeded => write!(f, "deadline exceeded"),
            SubmitError::Infeasible => {
                write!(f, "no device can meet the deadline budget at current load")
            }
            SubmitError::ShuttingDown => write!(f, "service shutting down"),
        }
    }
}
impl std::error::Error for SubmitError {}

/// How [`FleetController::remove_member`] disposes of a member's queued
/// work.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DrainMode {
    /// Stop admissions, then let the member's pipeline serve everything
    /// already queued before its threads are joined: every in-flight
    /// [`Ticket`] still resolves with its real result.
    Graceful,
    /// Stop admissions and shed the member's **admission queue**
    /// immediately: tickets still waiting there resolve with a "member
    /// removed" error (counted as `failed`). Requests already past the
    /// queue — grouped in the batcher's pending buffer or executing on
    /// a worker — run to completion (cooperative shedding: nothing is
    /// interrupted mid-flight), so callers must not assume Immediate
    /// cancels all unfinished work.
    Immediate,
}

/// One registered fleet member before startup.
struct MemberSpec {
    device: Option<DeviceDescriptor>,
    backend: Arc<dyn ResizeBackend>,
    policy: TilePolicy,
    manifest: Option<Manifest>,
}

/// Pipeline threads of one member, joined on removal/shutdown.
#[derive(Default)]
struct MemberThreads {
    batcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

/// A running fleet member: its own router, admission queue, batcher, and
/// worker pool. Members are shared (`Arc`) between topology snapshots,
/// so mutable lifecycle state lives behind atomics/locks.
struct Member {
    /// Registry id, unique across the fleet's lifetime (labels are not:
    /// a fleet may run several identical GPUs).
    id: u64,
    /// Shared with every ticket scheduled onto this member.
    label: Arc<str>,
    device: Option<DeviceDescriptor>,
    /// Hot-swappable routing table ([`FleetController::retune`] replaces
    /// the inner router while the pipeline keeps serving).
    router: SharedRouter,
    /// The manifest the router routes over, kept (shared, not copied)
    /// for retune rebuilds.
    manifest: Arc<Manifest>,
    stats: Arc<ServingStats>,
    /// Sim-cost oracle for this device (None for anonymous members).
    meter: Option<Arc<CostMeter>>,
    /// Cost-model estimate (ms/request) per supported key, for the
    /// scheduler's ETA computation. The table itself is immutable —
    /// retune swaps in a freshly built `Arc` — so submit plans hold it
    /// lock-free. Empty for anonymous members.
    cost: RwLock<Arc<HashMap<RequestKey, f64>>>,
    /// This member's dynamic-batch cap (capability-derived unless the
    /// config overrides it).
    batch_max: usize,
    /// Requests this member executes concurrently (workers × batch
    /// cap); the scheduler's ETA estimates divide the backlog by it.
    slots: u64,
    /// The member's admission-queue sender, used lock-free by the
    /// submit path (no per-submit clone, no mutex). Remove/shutdown
    /// **close** the channel instead of dropping the sender: closure
    /// works even while submit plans still hold this member, and a
    /// post-close send fails typed (the admission policies map it to
    /// [`SubmitError::ShuttingDown`]) instead of landing in a dead
    /// queue.
    admit_tx: Sender<ResizeRequest>,
    /// The member's queue, kept as the peers' steal surface and for
    /// `DrainMode::Immediate` shedding.
    admit_rx: Receiver<ResizeRequest>,
    /// The member's batching state, shared between its own batcher
    /// thread and peer thieves: a thief may claim a whole pending group
    /// (batch migration) so a freshly added member becomes useful
    /// within one batch window. Locked per operation, never while a
    /// second member's table is held.
    pending: Arc<Mutex<BatcherState>>,
    /// Set by `drain`/`remove_member`: the scheduler stops picking this
    /// member (stale snapshots included), while peers may still steal
    /// from — and its own pipeline still serves — its queue.
    draining: AtomicBool,
    threads: Mutex<MemberThreads>,
}

impl Member {
    fn is_draining(&self) -> bool {
        self.draining.load(Ordering::Acquire)
    }

    /// Requests grouped in the batcher's pending buffer right now — the
    /// migration analogue of `admit_rx.len()`.
    fn pending_len(&self) -> usize {
        self.pending.lock().unwrap().pending_len()
    }

    fn join_threads(&self) {
        // Take the handles out under the lock, join OUTSIDE it: a slow
        // worker drain must not block every other thread touching the
        // handle table for its whole shutdown (and `analyze`'s
        // no-guard-across-block rule pins this shape).
        let (batcher, workers) = {
            let mut t = self.threads.lock().unwrap();
            (t.batcher.take(), std::mem::take(&mut t.workers))
        };
        if let Some(b) = batcher {
            let _ = b.join();
        }
        for w in workers {
            let _ = w.join();
        }
    }
}

/// One epoch-stamped membership snapshot. Readers (`submit`, batchers,
/// thieves, [`FleetController::topology`]) clone the `Arc` and work on a
/// consistent view; writers publish a new snapshot with `epoch + 1`.
struct Topology {
    epoch: u64,
    members: Vec<Arc<Member>>,
}

/// The versioned membership registry handle shared by the fleet, its
/// controllers, and every member's batcher thread.
type SharedTopology = Arc<RwLock<Arc<Topology>>>;

/// Live work-stealing knobs, read per decision by batchers and the
/// submit-path snapshot builder; swapped by
/// [`FleetController::set_steal_config`].
struct StealRuntime {
    enabled: AtomicBool,
    threshold: AtomicUsize,
}

impl StealRuntime {
    fn new(enabled: bool, threshold: usize) -> StealRuntime {
        StealRuntime {
            enabled: AtomicBool::new(enabled),
            threshold: AtomicUsize::new(threshold.max(1)),
        }
    }

    fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Acquire)
    }

    fn threshold(&self) -> usize {
        self.threshold.load(Ordering::Acquire)
    }
}

/// One member's entry in a [`SubmitPlan`]: the member handle plus its
/// router and cost table **frozen at plan-build time**. Submits read
/// these without touching the member's `RwLock`s; a retune publishes a
/// new plan instead of mutating this one.
struct PlanMember {
    member: Arc<Member>,
    /// The member's routing table when the plan was built.
    router: Arc<Router>,
    /// The member's scheduler cost table (ms per supported key) when
    /// the plan was built.
    cost: Arc<HashMap<RequestKey, f64>>,
}

/// The immutable submit-path snapshot: everything [`Fleet::submit`]
/// needs to route one request — the live (non-draining) members with
/// their frozen routers and cost tables, the scheduler and admission
/// policies, and the steal knobs — bundled behind one `Arc` and
/// replaced atomically by the control plane
/// ([`FleetInner::rebuild_plan`]) on every reconfiguration.
struct SubmitPlan {
    /// Monotone plan version. Independent of the topology epoch, which
    /// tracks *membership* only: retunes and policy swaps bump the plan
    /// version without touching the epoch.
    version: u64,
    members: Vec<PlanMember>,
    scheduler: Arc<dyn Scheduler>,
    admission: Arc<dyn AdmissionPolicy>,
    /// Work-stealing enabled AND more than one plan member.
    steal_on: bool,
    steal_threshold: u64,
}

/// Counters instrumenting the submit fast path
/// ([`Fleet::plan_metrics`]). The hot-path invariant — steady-state
/// submit on an unchanged topology performs zero `RwLock`/`Mutex`
/// acquisitions and zero heap allocations — is observable here: a run
/// of submits bumps `fast_hits` only, while `refreshes` (plan `RwLock`
/// reads), `rebuilds` (control-plane plan builds), and `buf_grows`
/// (thread-local snapshot-buffer growth, the submit path's only
/// allocation source beyond the ticket it hands back) stay flat.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlanMetrics {
    /// Current plan version.
    pub version: u64,
    /// Submits served from the thread-local plan after the single
    /// atomic version check.
    pub fast_hits: u64,
    /// Submits that re-read the shared plan (the version moved, or a
    /// thread's first submit against this fleet).
    pub refreshes: u64,
    /// Plan rebuilds performed by the control plane.
    pub rebuilds: u64,
    /// Snapshot-buffer capacity growths (heap allocations) on the
    /// submit path.
    pub buf_grows: u64,
}

/// Per-thread submit state: the cached plan — revalidated against the
/// fleet's plan version by one atomic load per submit — and the
/// reusable device-snapshot buffer. Keyed by a process-unique fleet id,
/// NOT the `FleetInner` address: the allocator may hand a dropped
/// fleet's address to a new one (ABA), while the id counter never
/// repeats.
struct SubmitTls {
    fleet_id: u64,
    version: u64,
    plan: Option<Arc<SubmitPlan>>,
    buf: Vec<DeviceSnapshot>,
}

thread_local! {
    static SUBMIT_TLS: RefCell<SubmitTls> = RefCell::new(SubmitTls {
        fleet_id: u64::MAX,
        version: 0,
        plan: None,
        buf: Vec::new(),
    });
}

/// Process-wide fleet-id allocator backing the thread-local cache key.
static FLEET_IDS: AtomicU64 = AtomicU64::new(0);

/// Read-only view of one member for reporting (`tilekit serve`'s
/// per-device breakdown, `tilekit fleet topology`, tests). Owns `Arc`s
/// into the snapshot, so it stays valid across membership changes.
pub struct MemberView {
    /// Registry id (unique; labels may repeat).
    pub id: u64,
    /// Device id, or a synthetic `devN` label for anonymous members.
    pub label: Arc<str>,
    /// The device descriptor, when the member has an identity.
    pub device: Option<DeviceDescriptor>,
    /// The tile this member's router currently prefers.
    pub tile_pref: Option<TileDim>,
    /// The member's dynamic-batch cap (capability-derived unless the
    /// config overrides it).
    pub batch_max: usize,
    /// Requests waiting in this member's admission queue at snapshot
    /// time — the queue-depth signal policy loops (the autoscaler)
    /// sample.
    pub queued: u64,
    /// True once [`FleetController::drain`] (or a removal in progress)
    /// stopped new work from being scheduled onto this member.
    pub draining: bool,
    /// This member's serving stats.
    pub stats: Arc<ServingStats>,
    /// Snapshot of this member's current routing table (a retune after
    /// this call is not reflected).
    pub router: Arc<Router>,
}

impl MemberView {
    fn of(m: &Arc<Member>) -> MemberView {
        let router = Arc::clone(&m.router.read().unwrap());
        MemberView {
            id: m.id,
            label: Arc::clone(&m.label),
            device: m.device.clone(),
            tile_pref: router.tile_pref,
            batch_max: m.batch_max,
            queued: m.admit_rx.len() as u64,
            draining: m.is_draining(),
            stats: Arc::clone(&m.stats),
            router,
        }
    }
}

/// An epoch-stamped, read-only snapshot of the fleet's membership —
/// [`FleetController::topology`]'s introspection surface.
pub struct TopologyView {
    /// Monotone version of the membership; bumps on every add, remove,
    /// and drain.
    pub epoch: u64,
    /// All members, draining ones included.
    pub members: Vec<MemberView>,
}

/// Everything a member's batcher thread needs beyond its own queues: its
/// identity, and per-decision handles onto the registry and the live
/// steal knobs.
struct BatcherCtx {
    self_id: u64,
    batch_max: usize,
    topology: SharedTopology,
    steal: Arc<StealRuntime>,
}

/// The scheduler's ETA table: the cost-model estimate (ms) of ONE
/// request per supported key, through the variant `router` prefers.
fn cost_table(router: &Router, meter: Option<&CostMeter>) -> HashMap<RequestKey, f64> {
    let mut cost = HashMap::new();
    if let Some(m) = meter {
        for key in router.keys() {
            if let Ok(entry) = router.route(&key, 1) {
                let ms = m.ms_of(entry);
                if ms.is_finite() {
                    cost.insert(key, ms);
                }
            }
        }
    }
    cost
}

/// Builder for a [`Fleet`]. Register one or more members, then
/// [`build`](FleetBuilder::build). (`ServiceBuilder` is an alias.)
pub struct FleetBuilder {
    cfg: ServingConfig,
    manifest: Manifest,
    members: Vec<MemberSpec>,
    scheduler: Option<Box<dyn Scheduler>>,
    admission: Option<Box<dyn AdmissionPolicy>>,
    cost_model: Arc<dyn CostModel + Send + Sync>,
}

/// Compatibility alias for the pre-control-plane name.
#[deprecated(
    since = "0.2.0",
    note = "the data plane grew a control plane and was renamed: use `FleetBuilder` \
            (same type, same methods — a find/replace migrates callers)"
)]
pub type ServiceBuilder = FleetBuilder;

impl FleetBuilder {
    /// Start a builder over a shared artifact manifest. The config's
    /// `scheduler` / `admission` names supply the defaults (overridable
    /// with [`scheduler`](Self::scheduler) / [`admission`](Self::admission)).
    pub fn new(cfg: &ServingConfig, manifest: &Manifest) -> FleetBuilder {
        FleetBuilder {
            cfg: cfg.clone(),
            manifest: manifest.clone(),
            members: Vec::new(),
            scheduler: None,
            admission: None,
            cost_model: Arc::new(SimCostModel),
        }
    }

    /// Register a device member: its descriptor (identity + sim
    /// parameters), the backend executing its batches, and the tile
    /// policy its router resolves through (`TilePolicy::PerDevice`
    /// routes it to its tuned tile).
    pub fn device(
        mut self,
        device: DeviceDescriptor,
        backend: Arc<dyn ResizeBackend>,
        policy: TilePolicy,
    ) -> FleetBuilder {
        self.members.push(MemberSpec {
            device: Some(device),
            backend,
            policy,
            manifest: None,
        });
        self
    }

    /// Register a device member serving its own manifest instead of the
    /// shared one (heterogeneous artifact sets).
    pub fn device_with_manifest(
        mut self,
        device: DeviceDescriptor,
        backend: Arc<dyn ResizeBackend>,
        policy: TilePolicy,
        manifest: Manifest,
    ) -> FleetBuilder {
        self.members.push(MemberSpec {
            device: Some(device),
            backend,
            policy,
            manifest: Some(manifest),
        });
        self
    }

    /// Register an anonymous single-backend member (no device identity;
    /// no per-device tuning or cost estimates). This is the classic
    /// one-backend deployment.
    pub fn backend(
        mut self,
        backend: Arc<dyn ResizeBackend>,
        policy: TilePolicy,
    ) -> FleetBuilder {
        self.members.push(MemberSpec {
            device: None,
            backend,
            policy,
            manifest: None,
        });
        self
    }

    /// Override the scheduler (default: the config's `scheduler` name).
    pub fn scheduler(mut self, s: impl Scheduler + 'static) -> FleetBuilder {
        self.scheduler = Some(Box::new(s));
        self
    }

    /// Override the admission policy (default: the config's `admission`
    /// name with its `admission_timeout_ms`).
    pub fn admission(mut self, a: impl AdmissionPolicy + 'static) -> FleetBuilder {
        self.admission = Some(Box::new(a));
        self
    }

    /// Replace the cost model behind ETA scheduling and sim-cost
    /// metering (default: the timing simulator).
    pub fn cost_model(mut self, m: impl CostModel + Send + Sync + 'static) -> FleetBuilder {
        self.cost_model = Arc::new(m);
        self
    }

    /// Validate the config and start every member's pipeline.
    pub fn build(self) -> Result<Fleet> {
        self.cfg
            .validate()
            .context("invalid serving configuration")?;
        if self.members.is_empty() {
            bail!("service needs at least one device member");
        }
        let scheduler: Arc<dyn Scheduler> = match self.scheduler {
            Some(s) => Arc::from(s),
            None => Arc::from(scheduler_by_name(&self.cfg.scheduler)?),
        };
        let admission: Arc<dyn AdmissionPolicy> = match self.admission {
            Some(a) => Arc::from(a),
            None => Arc::from(admission_by_name(
                &self.cfg.admission,
                saturating_duration_from_ms(self.cfg.admission_timeout_ms),
            )?),
        };
        let steal = Arc::new(StealRuntime::new(
            self.cfg.work_stealing,
            self.cfg.steal_threshold,
        ));
        let inner = Arc::new(FleetInner {
            cfg: self.cfg,
            manifest: Arc::new(self.manifest),
            cost_model: self.cost_model,
            topology: Arc::new(RwLock::new(Arc::new(Topology {
                epoch: 0,
                members: Vec::new(),
            }))),
            next_member: AtomicU64::new(0),
            fleet_id: FLEET_IDS.fetch_add(1, Ordering::Relaxed),
            // Version-0 seed plan; each registration below republishes.
            plan: RwLock::new(Arc::new(SubmitPlan {
                version: 0,
                members: Vec::new(),
                scheduler: Arc::clone(&scheduler),
                admission: Arc::clone(&admission),
                steal_on: false,
                steal_threshold: 1,
            })),
            plan_version: AtomicU64::new(0),
            plan_fast_hits: Counter::default(),
            plan_refreshes: Counter::default(),
            plan_rebuilds: Counter::default(),
            plan_buf_grows: Counter::default(),
            submit_seq: AtomicU64::new(0),
            scheduler: RwLock::new(scheduler),
            admission: RwLock::new(admission),
            steal,
            local: Arc::new(ServingStats::new()),
            retiring: Mutex::new(Vec::new()),
            retired: ServingStats::new(),
            ids: IdGen::default(),
            closed: AtomicBool::new(false),
        });
        for spec in self.members {
            register_member(&inner, spec)?;
        }
        Ok(Fleet { inner })
    }
}

/// Resolve a member spec, start its pipeline (admission queue → batcher
/// thread → worker pool), and publish it into the registry under a new
/// epoch. The batcher doubles as the member's work-stealing thief: it
/// reads the topology per idle tick, so membership changes reach it
/// without a restart. Returns the member's registry id.
///
/// Publication re-checks `closed` under the topology write lock, so an
/// `add_member` racing a shutdown either lands in the snapshot the
/// shutdown joins, or is torn down here — never leaked.
fn register_member(inner: &Arc<FleetInner>, spec: MemberSpec) -> Result<u64> {
    let manifest = spec
        .manifest
        .map(Arc::new)
        .unwrap_or_else(|| Arc::clone(&inner.manifest));
    let id = inner.next_member.fetch_add(1, Ordering::Relaxed);
    let label: Arc<str> = spec
        .device
        .as_ref()
        .map(|d| d.id.clone())
        .unwrap_or_else(|| format!("dev{id}"))
        .into();
    let device_id = spec.device.as_ref().map(|d| d.id.clone());
    let router = Router::for_device(&manifest, spec.policy, device_id.as_deref());
    let meter = spec
        .device
        .clone()
        .map(|d| Arc::new(CostMeter::new(d, Arc::clone(&inner.cost_model))));
    let cost = cost_table(&router, meter.as_deref());
    let batch_max = inner.cfg.batch_max_for(spec.device.as_ref());
    let (admit_tx, admit_rx) = bounded::<ResizeRequest>(inner.cfg.queue_cap);
    let router = router.into_shared();
    let stats = Arc::new(ServingStats::new());

    let (batch_tx, batch_rx) = bounded::<Batch>(inner.cfg.queue_cap.max(4));
    // The batching state is shared (created BEFORE the batcher thread
    // spawns, then stored on the Member): the thread owns its lifecycle,
    // peer thieves lock it for whole-group batch migration.
    let pending = Arc::new(Mutex::new(BatcherState::new(
        batch_max,
        saturating_duration_from_ms(inner.cfg.batch_deadline_ms),
    )));
    let ctx = BatcherCtx {
        self_id: id,
        batch_max,
        topology: Arc::clone(&inner.topology),
        steal: Arc::clone(&inner.steal),
    };
    let batcher = {
        let stats = Arc::clone(&stats);
        let router = Arc::clone(&router);
        let admit_rx = admit_rx.clone();
        let pending = Arc::clone(&pending);
        std::thread::Builder::new()
            .name(format!("tilekit-batcher-{label}"))
            .spawn(move || run_batcher(ctx, admit_rx, batch_tx, stats, router, pending))
            .expect("spawn batcher")
    };
    let workers = spawn_workers(
        inner.cfg.workers,
        batch_rx,
        Arc::clone(&router),
        spec.backend,
        Arc::clone(&stats),
        meter.clone(),
    );

    let member = Arc::new(Member {
        id,
        label,
        device: spec.device,
        router,
        manifest,
        stats,
        meter,
        cost: RwLock::new(Arc::new(cost)),
        batch_max,
        slots: (inner.cfg.workers.max(1) * batch_max) as u64,
        admit_tx,
        admit_rx,
        pending,
        draining: AtomicBool::new(false),
        threads: Mutex::new(MemberThreads {
            batcher: Some(batcher),
            workers,
        }),
    });
    let mut guard = inner.topology.write().unwrap();
    if inner.is_closed() {
        // Shutdown ran between the caller's open-check and our publish:
        // the member is not in the snapshot shutdown joined, so tear its
        // pipeline down here instead of leaking the threads.
        drop(guard);
        member.admit_tx.close();
        member.join_threads();
        bail!("fleet is shut down");
    }
    let mut members = guard.members.clone();
    members.push(member);
    *guard = Arc::new(Topology {
        epoch: guard.epoch + 1,
        members,
    });
    drop(guard);
    // Republish the submit plan so the data plane routes to the new
    // member (must run after the topology lock is released — the
    // rebuild takes its own read lock).
    inner.rebuild_plan();
    Ok(id)
}

/// The batcher thread body: drain admissions, group, shed
/// cancelled/expired, flush on size/deadline — and, when idle, read the
/// current topology and steal compatible pending work from the hottest
/// peer queue over the threshold, or claim a whole pending group from
/// the deepest peer's batcher (batch migration) when the queues are
/// quiet but a pending table is not.
///
/// The batching state is the member's shared `pending` table; this
/// thread locks it per operation (never across a blocking send), so
/// peer thieves can migrate groups out between operations.
fn run_batcher(
    ctx: BatcherCtx,
    admit_rx: Receiver<ResizeRequest>,
    batch_tx: Sender<Batch>,
    stats: Arc<ServingStats>,
    router: SharedRouter,
    pending: Arc<Mutex<BatcherState>>,
) {
    // Adaptive idle poll: 50ms while the fleet is quiet, dropping to
    // STEAL_POLL only while some peer sits at/over the steal threshold
    // (re-checked on every idle tick).
    let mut peers_hot = false;
    loop {
        let timeout = match pending.lock().unwrap().next_deadline(Instant::now()) {
            // While requests are pending, poll fast enough to shed
            // cancellations/deadlines promptly.
            Some(d) => d.min(SHED_POLL),
            None if peers_hot => STEAL_POLL,
            None => Duration::from_millis(50),
        };
        match admit_rx.recv_timeout(timeout) {
            Ok(Some(req)) => {
                let full = pending.lock().unwrap().push(req);
                if let Some(batch) = full {
                    if batch_tx.send(batch).is_err() {
                        return; // workers gone
                    }
                }
            }
            Ok(None) => {
                // Timed out with an empty queue. If nothing is pending
                // locally either, this member is idle — try to steal.
                // Paced by our own unanswered backlog (under two
                // batches' worth): a thief must not hoard work faster
                // than it executes, only keep its own pipeline fed.
                // While the pacing gate blocks, the fast tick persists
                // on purpose: it is the pacing poll, bounded by our own
                // workers' drain time (a batch or two), and dropping to
                // the slow tick there would cap the steady-state steal
                // rate at one attempt per 50ms.
                peers_hot = false;
                if ctx.steal.enabled() {
                    let threshold = ctx.steal.threshold();
                    let topo = Arc::clone(&ctx.topology.read().unwrap());
                    // A draining member (or one already removed from the
                    // registry) must not pull NEW work onto itself — it
                    // only finishes what it already owns.
                    let self_draining =
                        match topo.members.iter().find(|m| m.id == ctx.self_id) {
                            Some(me) => me.is_draining(),
                            None => true,
                        };
                    let peers: Vec<&Arc<Member>> = topo
                        .members
                        .iter()
                        .filter(|m| m.id != ctx.self_id)
                        .collect();
                    // A peer is hot when its admission queue crosses the
                    // steal threshold OR its pending table holds a
                    // migratable group — the latter is how a fresh
                    // member notices a batch worth claiming even though
                    // every queue is shallow.
                    peers_hot = !self_draining
                        && peers.iter().any(|p| {
                            p.admit_rx.len() >= threshold
                                || (!p.is_draining()
                                    && p.pending_len() >= MIGRATE_MIN_LIVE.max(threshold))
                        });
                    if peers_hot
                        && pending.lock().unwrap().pending_len() == 0
                        && stats.inflight() < 2 * ctx.batch_max as u64
                    {
                        let policy = StealPolicy {
                            min_victim_backlog: threshold,
                            // Steal at most one batch's worth per attempt.
                            max_per_attempt: ctx.batch_max,
                        };
                        let (stole, mut batches) =
                            steal_from_peers(&policy, &peers, &router, &stats, &pending);
                        let mut moved = stole;
                        if stole == 0 {
                            // No queue to raid — claim a whole pending
                            // group instead, so scale-up pays off inside
                            // one batch window: the migrated requests
                            // keep their original admission times, so
                            // the deadline flush below fires promptly.
                            let (migrated, more) =
                                migrate_from_peers(&peers, &router, &stats, &pending);
                            moved = migrated;
                            batches.extend(more);
                        }
                        for batch in batches {
                            if batch_tx.send(batch).is_err() {
                                return;
                            }
                        }
                        // A deep peer whose work we cannot route (or
                        // that is all cancelled/expired) yields nothing;
                        // drop back to the slow idle tick instead of
                        // re-scanning its queue every STEAL_POLL.
                        if moved == 0 {
                            peers_hot = false;
                        }
                    }
                }
            }
            Err(_) => break, // admissions closed: shutdown
        }
        let swept = pending.lock().unwrap().sweep(Instant::now());
        for (req, reason) in swept {
            let (counter, msg) = match reason {
                Shed::Cancelled => (&stats.cancelled, "cancelled"),
                Shed::DeadlineExceeded => (&stats.shed, "deadline exceeded before execution"),
            };
            counter.inc();
            let _ = req
                .reply
                .send(Err(anyhow::anyhow!("request {} {msg}", req.id)));
        }
        let expired = pending.lock().unwrap().flush_expired(Instant::now());
        for batch in expired {
            if batch_tx.send(batch).is_err() {
                return;
            }
        }
    }
    // Shutdown: flush everything still pending.
    let rest = pending.lock().unwrap().flush_all();
    for batch in rest {
        let _ = batch_tx.send(batch);
    }
}

/// One steal attempt by an idle member: pick the deepest peer queue at
/// or over the backlog threshold, take a compatible slice of its newest
/// requests (see [`select_steals`] for the invariants), account the
/// ownership transfer on both sides, and push the loot into the thief's
/// batcher state. Returns how many requests were stolen and any batches
/// the loot filled.
fn steal_from_peers(
    policy: &StealPolicy,
    peers: &[&Arc<Member>],
    router: &SharedRouter,
    stats: &ServingStats,
    pending: &Mutex<BatcherState>,
) -> (usize, Vec<Batch>) {
    let Some(victim) = peers
        .iter()
        .filter(|p| p.admit_rx.len() >= policy.min_victim_backlog)
        .max_by_key(|p| p.admit_rx.len())
    else {
        return (0, Vec::new());
    };
    let current = Arc::clone(&router.read().expect("router lock"));
    let now = Instant::now();
    let loot = victim.admit_rx.steal_by(|q| {
        select_steals(q, |key| current.supports(key), now, policy.max_per_attempt)
    });
    let stole = loot.len();
    let mut batches = Vec::new();
    for req in loot {
        victim.stats.stolen.inc();
        stats.steals.inc();
        if let Some(batch) = pending.lock().unwrap().push(req) {
            batches.push(batch);
        }
    }
    (stole, batches)
}

/// One whole-batch migration attempt by an idle member: scan the
/// non-draining peers' pending tables (deepest first) for the fullest
/// group the thief can route (see [`select_batch_migration`] for the
/// invariants), extract it under the victim's lock, and re-home the
/// live requests into the thief's own pending table — where they keep
/// their original admission times, so the deadline flush batches them
/// through the thief's tuned tile within one poll. Cancelled/expired
/// requests found in the group are shed victim-side with the same
/// accounting as the victim's own sweep.
///
/// Selection and extraction happen under ONE victim lock (the group
/// cannot flush in between), and that lock is released before the
/// thief's own table is taken — never two pending locks at once.
fn migrate_from_peers(
    peers: &[&Arc<Member>],
    router: &SharedRouter,
    stats: &ServingStats,
    pending: &Mutex<BatcherState>,
) -> (usize, Vec<Batch>) {
    let current = Arc::clone(&router.read().expect("router lock"));
    let now = Instant::now();
    let mut ordered: Vec<&Arc<Member>> = peers
        .iter()
        .copied()
        .filter(|p| !p.is_draining())
        .collect();
    ordered.sort_by_key(|p| std::cmp::Reverse(p.pending_len()));
    for victim in ordered {
        let taken = {
            let mut table = victim.pending.lock().unwrap();
            let groups = table.migration_groups(now);
            let Some(i) = select_batch_migration(
                &groups,
                |key| current.supports(key),
                victim.is_draining(),
                MIGRATE_MIN_LIVE,
            ) else {
                continue;
            };
            table.take_group(&groups[i].key)
        };
        let mut migrated = 0;
        let mut batches = Vec::new();
        for req in taken {
            let cancelled = req.is_cancelled();
            if cancelled || req.is_expired(now) {
                let (counter, msg) = if cancelled {
                    (&victim.stats.cancelled, "cancelled")
                } else {
                    (&victim.stats.shed, "deadline exceeded before execution")
                };
                counter.inc();
                let _ = req
                    .reply
                    .send(Err(anyhow::anyhow!("request {} {msg}", req.id)));
                continue;
            }
            // Ownership transfer, accounted exactly like a queue steal
            // (the victim admitted it, the thief answers it), plus the
            // migration counter once per claimed group.
            victim.stats.stolen.inc();
            stats.steals.inc();
            migrated += 1;
            if let Some(batch) = pending.lock().unwrap().push(req) {
                batches.push(batch);
            }
        }
        if migrated > 0 {
            stats.migrated_batches.inc();
        }
        return (migrated, batches);
    }
    (0, Vec::new())
}

/// Shared state behind both planes: the data plane ([`Fleet`]) and any
/// number of control-plane handles ([`FleetController`]).
struct FleetInner {
    cfg: ServingConfig,
    manifest: Arc<Manifest>,
    cost_model: Arc<dyn CostModel + Send + Sync>,
    topology: SharedTopology,
    next_member: AtomicU64,
    /// Process-unique id keying the thread-local submit caches.
    fleet_id: u64,
    /// The current submit plan. Submitters touch this `RwLock` only
    /// when `plan_version` moved; every control-plane mutation
    /// republishes through [`rebuild_plan`](Self::rebuild_plan).
    plan: RwLock<Arc<SubmitPlan>>,
    /// Version of the published plan; the submit fast path's single
    /// atomic load.
    plan_version: AtomicU64,
    plan_fast_hits: Counter,
    plan_refreshes: Counter,
    plan_rebuilds: Counter,
    plan_buf_grows: Counter,
    /// Submit sequence number driving breakdown sampling
    /// (`cfg.breakdown_sample`).
    submit_seq: AtomicU64,
    scheduler: RwLock<Arc<dyn Scheduler>>,
    admission: RwLock<Arc<dyn AdmissionPolicy>>,
    steal: Arc<StealRuntime>,
    /// Submit-side counters (unsupported rejections, fail-fast deadline
    /// sheds) that belong to no single member.
    local: Arc<ServingStats>,
    /// Members mid-removal: out of the topology but not yet folded into
    /// `retired`, kept visible to [`FleetInner::merged_stats`] so fleet
    /// totals never dip during the drain window. The same lock guards
    /// `retired`, making the hand-off atomic for readers.
    retiring: Mutex<Vec<Arc<Member>>>,
    /// Final stats of removed members, merged in after their threads
    /// joined, so fleet totals survive membership churn.
    retired: ServingStats,
    ids: IdGen,
    closed: AtomicBool,
}

impl FleetInner {
    fn is_closed(&self) -> bool {
        self.closed.load(Ordering::Acquire)
    }

    fn snapshot(&self) -> Arc<Topology> {
        Arc::clone(&self.topology.read().unwrap())
    }

    /// Rebuild the immutable submit plan from the current topology and
    /// policies and publish it under the next version. Called by every
    /// control-plane mutation, after the mutation's own locks are
    /// released — the rebuild takes the topology **read** lock, and the
    /// `RwLock` is not reentrant. Rebuilds serialize on the plan write
    /// lock; the version is stored (`Release`) while that lock is still
    /// held, so a submitter that observes the new version always reads
    /// a plan at least that fresh.
    fn rebuild_plan(&self) {
        let mut slot = self.plan.write().unwrap();
        let members: Vec<PlanMember> = if self.is_closed() {
            // Post-shutdown plan: empty, so thread-local caches drop
            // their member references on their next submit attempt.
            Vec::new()
        } else {
            let topo = self.topology.read().unwrap();
            topo.members
                .iter()
                .filter(|m| !m.is_draining())
                .map(|m| PlanMember {
                    router: Arc::clone(&m.router.read().unwrap()),
                    cost: Arc::clone(&m.cost.read().unwrap()),
                    member: Arc::clone(m),
                })
                .collect()
        };
        let steal_on = self.steal.enabled() && members.len() > 1;
        // analyze::allow(atomics-pairing): single-writer read — every
        // plan_version store happens under the plan write lock we hold,
        // so this Relaxed load observes the latest value; readers
        // pairing with the Release store below still use Acquire.
        let version = self.plan_version.load(Ordering::Relaxed) + 1;
        *slot = Arc::new(SubmitPlan {
            version,
            members,
            scheduler: Arc::clone(&self.scheduler.read().unwrap()),
            admission: Arc::clone(&self.admission.read().unwrap()),
            steal_on,
            steal_threshold: self.steal.threshold() as u64,
        });
        self.plan_rebuilds.inc();
        self.plan_version.store(version, Ordering::Release);
    }

    /// Idempotent full shutdown: stop admissions on every member, then
    /// join all pipelines.
    fn shutdown(&self) {
        if self.closed.swap(true, Ordering::AcqRel) {
            return;
        }
        let topo = self.snapshot();
        for m in &topo.members {
            // Closing admissions: batcher exits, then workers exit.
            // Close (not drop) fails the sends of submitters still on a
            // stale plan instead of leaving their requests in a queue
            // nobody drains.
            m.admit_tx.close();
        }
        for m in &topo.members {
            m.join_threads();
        }
        // Publish an empty plan so cached snapshots stop routing and
        // drop their member references.
        self.rebuild_plan();
    }

    /// Merged fleet-wide stats: submit-side + retired + retiring + live
    /// members. The topology read lock is held across both reads (lock
    /// order: topology → retiring, matching every writer), so a member
    /// mid-removal is counted in exactly one of topology/retiring/
    /// retired — fleet totals never dip or double-count during churn.
    fn merged_stats(&self) -> ServingStats {
        let total = ServingStats::new();
        total.merge_from(&self.local);
        let topo = self.topology.read().unwrap();
        {
            let retiring = self.retiring.lock().unwrap();
            total.merge_from(&self.retired);
            for m in retiring.iter() {
                total.merge_from(&m.stats);
            }
        }
        for m in &topo.members {
            total.merge_from(&m.stats);
        }
        total
    }

    /// The submit body, routed over one immutable plan. Everything it
    /// touches is either plan-frozen (routers, cost tables, policies),
    /// atomic (stats counters, queue-depth mirrors, the id generator),
    /// or caller-owned (the reusable snapshot buffer) — no
    /// `RwLock`/`Mutex` and no allocation besides the ticket's reply
    /// channel, which is the caller's deliverable. `t0` is the sampled
    /// breakdown start time (None = this submit is unsampled).
    fn submit_on_plan(
        &self,
        plan: &SubmitPlan,
        buf: &mut Vec<DeviceSnapshot>,
        req: Request,
        t0: Option<Instant>,
    ) -> Result<Ticket, SubmitError> {
        if plan.members.is_empty() {
            // Every member is draining or removed. That is not an
            // unsupported shape — it is a temporarily unschedulable
            // fleet (an add_member may follow), so report the retryable
            // error instead of Unsupported.
            return Err(SubmitError::ShuttingDown);
        }
        let key = req.key();
        let now = Instant::now();
        // Refill the thread-local snapshot buffer in place: steady
        // state reuses its capacity (growth is counted — see
        // [`PlanMetrics::buf_grows`]).
        buf.clear();
        if buf.capacity() < plan.members.len() {
            self.plan_buf_grows.inc();
            buf.reserve(plan.members.len());
        }
        for (index, pm) in plan.members.iter().enumerate() {
            let m = &pm.member;
            let queued = m.admit_rx.len() as u64;
            buf.push(DeviceSnapshot {
                index,
                device_id: Arc::clone(&m.label),
                supports: pm.router.supports(&key),
                // inflight() = owned - answered, which already covers
                // requests still sitting in the admission queue (and
                // accounts for work stolen to/from this member).
                inflight: m.stats.inflight(),
                cost_ms: pm.cost.get(&key).copied(),
                slots: m.slots,
                queued,
                // Peers' idle capacity will drain a backlog the steal
                // threshold already exposes — let the scheduler
                // discount it (see scheduler::steal_discount).
                stealable: plan.steal_on && queued >= plan.steal_threshold,
            });
        }
        let t1 = t0.map(|_| Instant::now());
        // Unserveable beats expired: a request nobody can route is
        // Unsupported no matter what its budget says.
        if !buf.iter().any(|s| s.supports) {
            self.local.rejected.inc();
            return Err(SubmitError::Unsupported);
        }
        let deadline = match req.deadline {
            Some(budget) if budget.is_zero() => {
                // Fail fast instead of occupying a queue slot.
                self.local.shed.inc();
                return Err(SubmitError::DeadlineExceeded);
            }
            Some(budget) => {
                // Deadline-aware admission: decline a budget no member's
                // queue-depth-aware ETA can meet, instead of accepting
                // work the pipeline would shed later.
                if let Some(eta_ms) = plan.scheduler.min_eta_ms(&key, buf) {
                    if eta_ms.is_finite() && eta_ms / 1e3 > budget.as_secs_f64() {
                        self.local.infeasible.inc();
                        return Err(SubmitError::Infeasible);
                    }
                }
                Some(now + budget)
            }
            None => None,
        };
        let Some(index) = plan.scheduler.pick(&key, buf) else {
            self.local.rejected.inc();
            return Err(SubmitError::Unsupported);
        };
        // The invariant the old path re-locked the router to check:
        // asserted against the snapshot's cached bit instead.
        debug_assert!(
            buf[index].supports,
            "scheduler picked a member that cannot route the key"
        );
        let t2 = t0.map(|_| Instant::now());
        let member = &plan.members[index].member;
        let id = self.ids.next();
        let (ticket, reply) =
            Ticket::for_device(id, Default::default(), Some(Arc::clone(&member.label)));
        let rr = ResizeRequest {
            id,
            key,
            image: req.image,
            priority: req.priority,
            deadline,
            // The ticket and the pipeline share the same token.
            cancel: ticket.cancel_token(),
            admitted: now,
            reply,
        };
        // Count the admission BEFORE the enqueue: the moment the request
        // is in the queue an idle peer may steal (and even answer) it,
        // and the victim's accounting must never observe a stolen
        // request that was not yet admitted. A failed enqueue rolls the
        // optimistic count back.
        member.stats.admitted.inc();
        match plan.admission.admit(&member.admit_tx, rr) {
            Ok(()) => {
                if let (Some(a), Some(b), Some(c)) = (t0, t1, t2) {
                    let done = Instant::now();
                    self.local.submit_snapshot.record(b - a);
                    self.local.submit_schedule.record(c - b);
                    self.local.submit_admit.record(done - c);
                }
                Ok(ticket)
            }
            Err(e) => {
                member.stats.admitted.sub(1);
                // Only backpressure counts as a member rejection; a
                // budget that ran out while blocked is a shed — recorded
                // service-side, NOT on the member, because the request
                // was never admitted and member shed/admitted counters
                // must stay balanced for inflight(). A shutdown race —
                // a plan that outlived its member's removal — is
                // neither: the caller retries and the refreshed plan
                // routes around it.
                match e {
                    SubmitError::Saturated => member.stats.rejected.inc(),
                    SubmitError::DeadlineExceeded => self.local.shed.inc(),
                    _ => {}
                }
                Err(e)
            }
        }
    }
}

/// The data plane: the running fleet-aware serving system. Submit typed
/// requests; reconfigure it live through [`Fleet::controller`].
/// (`Service` is an alias.)
pub struct Fleet {
    inner: Arc<FleetInner>,
}

/// Compatibility alias for the pre-control-plane name.
#[deprecated(
    since = "0.2.0",
    note = "the data plane grew a control plane and was renamed: use `Fleet` \
            (same type, same methods — a find/replace migrates callers)"
)]
pub type Service = Fleet;

impl Fleet {
    /// Convenience: a single-member service over one backend (the old
    /// `Coordinator::start` deployment shape).
    pub fn single(
        cfg: &ServingConfig,
        manifest: &Manifest,
        backend: Arc<dyn ResizeBackend>,
        policy: TilePolicy,
    ) -> Result<Fleet> {
        FleetBuilder::new(cfg, manifest)
            .backend(backend, policy)
            .build()
    }

    /// A control-plane handle onto this fleet. Cheap to clone; stays
    /// valid (but starts erroring) after the fleet shuts down.
    pub fn controller(&self) -> FleetController {
        FleetController {
            inner: Arc::clone(&self.inner),
        }
    }

    /// Submit a typed request. The scheduler picks the member over the
    /// current [`SubmitPlan`], the admission policy decides what a
    /// full queue means — and, when the scheduler can price the request,
    /// a deadline budget below the best queue-depth-aware ETA is
    /// declined as [`SubmitError::Infeasible`].
    ///
    /// Hot path: one `Relaxed` fetch-add (breakdown sampling), one
    /// `Acquire` load of the plan version, then a routing pass over the
    /// thread-cached plan. The topology `RwLock` is never touched; the
    /// plan `RwLock` is read only when the version moved (a control-plane
    /// mutation landed since this thread last submitted).
    pub fn submit(&self, req: Request) -> Result<Ticket, SubmitError> {
        if self.inner.is_closed() {
            return Err(SubmitError::ShuttingDown);
        }
        SUBMIT_TLS.with(|cell| {
            let mut tls = cell.borrow_mut();
            // Destructure so the cached plan and the snapshot buffer
            // borrow disjointly.
            let SubmitTls {
                fleet_id,
                version,
                plan: slot,
                buf,
            } = &mut *tls;
            let inner = &*self.inner;
            let sample = inner.cfg.breakdown_sample != 0
                && inner.submit_seq.fetch_add(1, Ordering::Relaxed) % inner.cfg.breakdown_sample
                    == 0;
            let t0 = if sample { Some(Instant::now()) } else { None };
            let current = inner.plan_version.load(Ordering::Acquire);
            if *fleet_id != inner.fleet_id || *version != current || slot.is_none() {
                // Version moved (or this thread last served a different
                // fleet): refresh the cache from the shared slot.
                let fresh = Arc::clone(&inner.plan.read().unwrap());
                *fleet_id = inner.fleet_id;
                *version = fresh.version;
                *slot = Some(fresh);
                inner.plan_refreshes.inc();
            } else {
                inner.plan_fast_hits.inc();
            }
            let plan = slot.as_ref().expect("plan cached above");
            inner.submit_on_plan(plan, buf, req, t0)
        })
    }

    /// Live counters for the lock-free submit fast path. Test and
    /// diagnostics hook: steady-state traffic should advance only
    /// `fast_hits`.
    pub fn plan_metrics(&self) -> PlanMetrics {
        PlanMetrics {
            version: self.inner.plan_version.load(Ordering::Acquire),
            fast_hits: self.inner.plan_fast_hits.get(),
            refreshes: self.inner.plan_refreshes.get(),
            rebuilds: self.inner.plan_rebuilds.get(),
            buf_grows: self.inner.plan_buf_grows.get(),
        }
    }

    /// The union of keys any member can serve, sorted.
    pub fn keys(&self) -> Vec<RequestKey> {
        let mut ks: Vec<RequestKey> = self
            .inner
            .snapshot()
            .members
            .iter()
            .flat_map(|m| m.router.read().unwrap().keys())
            .collect();
        ks.sort();
        ks.dedup();
        ks
    }

    /// Number of fleet members (draining ones included).
    pub fn member_count(&self) -> usize {
        self.inner.snapshot().members.len()
    }

    /// Read-only views of every member, for per-device reporting.
    pub fn members(&self) -> Vec<MemberView> {
        self.inner
            .snapshot()
            .members
            .iter()
            .map(MemberView::of)
            .collect()
    }

    /// The scheduler in use.
    pub fn scheduler_name(&self) -> &'static str {
        self.inner.scheduler.read().unwrap().name()
    }

    /// The admission policy in use.
    pub fn admission_name(&self) -> &'static str {
        self.inner.admission.read().unwrap().name()
    }

    /// Merged fleet-wide stats snapshot (counters + histograms summed
    /// over submit-side, removed, and live members; live stats keep
    /// updating after the call).
    pub fn stats(&self) -> ServingStats {
        self.inner.merged_stats()
    }

    /// Reset every member's stats (e.g. after a warmup phase), including
    /// the retained stats of removed members and of members mid-removal
    /// (whose final counters would otherwise be folded into the totals
    /// after the reset).
    pub fn reset_stats(&self) {
        self.inner.local.reset();
        let topo = self.inner.topology.read().unwrap();
        {
            let retiring = self.inner.retiring.lock().unwrap();
            self.inner.retired.reset();
            for m in retiring.iter() {
                m.stats.reset();
            }
        }
        for m in &topo.members {
            m.stats.reset();
        }
    }

    /// Graceful shutdown: stop admissions, drain every member's
    /// pipeline, join all threads. Returns the final merged stats.
    pub fn shutdown(self) -> ServingStats {
        self.inner.shutdown();
        self.inner.merged_stats()
    }
}

impl Drop for Fleet {
    fn drop(&mut self) {
        self.inner.shutdown();
    }
}

/// The typed control plane: lifecycle and reconfiguration commands
/// against a live [`Fleet`], applicable without restarting workers.
/// Obtain one with [`Fleet::controller`]; clones share the same fleet.
///
/// Every mutation publishes a new epoch-stamped topology snapshot (or
/// swaps an `Arc`'d policy), so concurrent submits and batcher decisions
/// observe either the old or the new configuration, never a torn one.
#[derive(Clone)]
pub struct FleetController {
    inner: Arc<FleetInner>,
}

impl FleetController {
    fn ensure_open(&self) -> Result<()> {
        if self.inner.is_closed() {
            bail!("fleet is shut down");
        }
        Ok(())
    }

    /// Add a device member to the live fleet: the scheduler sees it on
    /// the next submit, and peers' batchers on their next idle tick.
    /// Returns the member's registry id.
    pub fn add_member(
        &self,
        device: DeviceDescriptor,
        backend: Arc<dyn ResizeBackend>,
        policy: TilePolicy,
    ) -> Result<u64> {
        self.ensure_open()?;
        register_member(
            &self.inner,
            MemberSpec {
                device: Some(device),
                backend,
                policy,
                manifest: None,
            },
        )
    }

    /// Add a device member serving its own manifest (heterogeneous
    /// artifact sets).
    pub fn add_member_with_manifest(
        &self,
        device: DeviceDescriptor,
        backend: Arc<dyn ResizeBackend>,
        policy: TilePolicy,
        manifest: Manifest,
    ) -> Result<u64> {
        self.ensure_open()?;
        register_member(
            &self.inner,
            MemberSpec {
                device: Some(device),
                backend,
                policy,
                manifest: Some(manifest),
            },
        )
    }

    /// Add an anonymous single-backend member (no device identity).
    pub fn add_backend(
        &self,
        backend: Arc<dyn ResizeBackend>,
        policy: TilePolicy,
    ) -> Result<u64> {
        self.ensure_open()?;
        register_member(
            &self.inner,
            MemberSpec {
                device: None,
                backend,
                policy,
                manifest: None,
            },
        )
    }

    /// Remove every member labeled `device_id` from the live fleet.
    /// The members leave the topology immediately (no new work is
    /// scheduled onto them, stale snapshots included); their queued work
    /// is disposed of per [`DrainMode`], their threads are joined, and
    /// their final stats are retained in the fleet totals.
    pub fn remove_member(&self, device_id: &str, mode: DrainMode) -> Result<()> {
        self.ensure_open()?;
        let removed: Vec<Arc<Member>> = {
            let mut guard = self.inner.topology.write().unwrap();
            let (gone, keep): (Vec<_>, Vec<_>) = guard
                .members
                .iter()
                .cloned()
                .partition(|m| &*m.label == device_id);
            if gone.is_empty() {
                bail!("no fleet member '{device_id}'");
            }
            // Hand the members to the retiring list under the SAME
            // topology write lock that unpublishes them, so stats
            // readers (topology read → retiring, same order) see each
            // member in exactly one place.
            self.inner
                .retiring
                .lock()
                .unwrap()
                .extend(gone.iter().cloned());
            *guard = Arc::new(Topology {
                epoch: guard.epoch + 1,
                members: keep,
            });
            gone
        };
        // Unpublish from the submit plan before closing the queues:
        // refreshed submitters route around the member while stale plans
        // fail typed (the closed channel) rather than losing work.
        self.inner.rebuild_plan();
        for m in &removed {
            m.draining.store(true, Ordering::Release);
            // Closing the member's channel lets its batcher drain the
            // queue and exit; requests already admitted (including via
            // stale plans) stay visible to the batcher until they
            // resolve, so nothing is lost — only post-close sends fail.
            m.admit_tx.close();
            if mode == DrainMode::Immediate {
                for req in m.admit_rx.drain_now() {
                    m.stats.failed.inc();
                    let _ = req.reply.send(Err(anyhow::anyhow!(
                        "request {} dropped: member '{device_id}' removed",
                        req.id
                    )));
                }
            }
        }
        for m in &removed {
            m.join_threads();
            // Counters are final once the pipeline joined; fold them
            // into the retained totals and drop the retiring entry in
            // one critical section so readers never see both or neither.
            let mut retiring = self.inner.retiring.lock().unwrap();
            self.inner.retired.merge_from(&m.stats);
            retiring.retain(|r| r.id != m.id);
        }
        Ok(())
    }

    /// Stop scheduling new work onto every member labeled `device_id`
    /// while keeping it in the fleet: its pipeline (and its peers'
    /// thieves) drain what it already holds. A later
    /// [`remove_member`](Self::remove_member) completes the retirement.
    pub fn drain(&self, device_id: &str) -> Result<()> {
        self.ensure_open()?;
        let mut guard = self.inner.topology.write().unwrap();
        let mut found = false;
        for m in guard.members.iter().filter(|m| &*m.label == device_id) {
            found = true;
            m.draining.store(true, Ordering::Release);
        }
        if !found {
            bail!("no fleet member '{device_id}'");
        }
        // Publish the flag under a new epoch so observers see the change.
        *guard = Arc::new(Topology {
            epoch: guard.epoch + 1,
            members: guard.members.clone(),
        });
        // rebuild_plan takes the topology read lock — release ours first
        // (the RwLock is not reentrant).
        drop(guard);
        self.inner.rebuild_plan();
        Ok(())
    }

    /// Hot-swap a device's tuned tile after a tuning refresh (e.g. a
    /// [`TuningDb`](crate::autotuner::TuningDb) cache update) changed
    /// the winner: rebuild the router of **every** member with this
    /// device id (a fleet may run several identical GPUs) under
    /// `TilePolicy::PerDevice(outcome)` and refresh the scheduler's ETA
    /// tables, **without draining the fleet** — batches already picked
    /// up keep the router they started with; the next batch routes
    /// through the new tile. Returns the new preferred tile.
    pub fn retune(&self, device_id: &str, outcome: &TuningOutcome) -> Result<Option<TileDim>> {
        self.ensure_open()?;
        let topo = self.inner.snapshot();
        let mut tile = None;
        let mut found = false;
        for member in topo.members.iter().filter(|m| &*m.label == device_id) {
            found = true;
            let identity = member.device.as_ref().map(|d| d.id.as_str());
            let next = Arc::new(Router::for_device(
                &member.manifest,
                TilePolicy::PerDevice(outcome.clone()),
                identity,
            ));
            let cost = cost_table(&next, member.meter.as_deref());
            // Cost table first: a scheduler snapshot between the two
            // writes sees a (new-cost, old-router) pair, which only
            // mis-prices one pick — both maps cover the same key set.
            *member.cost.write().unwrap() = Arc::new(cost);
            tile = next.tile_pref;
            *member.router.write().unwrap() = next;
            member.stats.retunes.inc();
        }
        if !found {
            bail!("no fleet member '{device_id}'");
        }
        // Republish so submitters see the (router, cost) swap: once this
        // returns, no refreshed submitter routes by the stale tile.
        self.inner.rebuild_plan();
        Ok(tile)
    }

    /// Swap the scheduler for all subsequent submits.
    pub fn set_scheduler(&self, s: impl Scheduler + 'static) -> Result<()> {
        self.ensure_open()?;
        *self.inner.scheduler.write().unwrap() = Arc::new(s);
        self.inner.rebuild_plan();
        Ok(())
    }

    /// Swap the scheduler by its CLI/config name.
    pub fn set_scheduler_by_name(&self, name: &str) -> Result<()> {
        self.ensure_open()?;
        let s: Arc<dyn Scheduler> = Arc::from(scheduler_by_name(name)?);
        *self.inner.scheduler.write().unwrap() = s;
        self.inner.rebuild_plan();
        Ok(())
    }

    /// Swap the admission policy for all subsequent submits.
    pub fn set_admission(&self, a: impl AdmissionPolicy + 'static) -> Result<()> {
        self.ensure_open()?;
        *self.inner.admission.write().unwrap() = Arc::new(a);
        self.inner.rebuild_plan();
        Ok(())
    }

    /// Swap the admission policy by its CLI/config name; `timeout` feeds
    /// the blocking variants.
    pub fn set_admission_by_name(&self, name: &str, timeout: Duration) -> Result<()> {
        self.ensure_open()?;
        let a: Arc<dyn AdmissionPolicy> = Arc::from(admission_by_name(name, timeout)?);
        *self.inner.admission.write().unwrap() = a;
        self.inner.rebuild_plan();
        Ok(())
    }

    /// Reconfigure work-stealing on the live fleet: batchers read these
    /// knobs per idle tick, the submit path per request.
    pub fn set_steal_config(&self, enabled: bool, threshold: usize) -> Result<()> {
        self.ensure_open()?;
        if threshold == 0 {
            bail!("steal threshold must be >= 1 (got 0)");
        }
        self.inner
            .steal
            .threshold
            .store(threshold, Ordering::Release);
        self.inner.steal.enabled.store(enabled, Ordering::Release);
        self.inner.rebuild_plan();
        Ok(())
    }

    /// An epoch-stamped snapshot of the current membership.
    pub fn topology(&self) -> TopologyView {
        let topo = self.inner.snapshot();
        TopologyView {
            epoch: topo.epoch,
            members: topo.members.iter().map(MemberView::of).collect(),
        }
    }

    /// Current membership epoch (bumps on add/remove/drain).
    pub fn epoch(&self) -> u64 {
        self.inner.snapshot().epoch
    }

    /// Merged fleet-wide stats snapshot — the same totals
    /// [`Fleet::stats`] reports, exposed on the control plane so
    /// background policy loops (the autoscaler) can sample load
    /// without holding a data-plane handle.
    pub fn stats(&self) -> ServingStats {
        self.inner.merged_stats()
    }

    /// The submit-side stats the fleet records control-plane events on
    /// (scale-ups/downs belong to the fleet, not to any one member).
    pub(crate) fn local_stats(&self) -> Arc<ServingStats> {
        Arc::clone(&self.inner.local)
    }

    /// Has the fleet shut down? (Control commands error afterwards;
    /// background daemons use this to exit.)
    pub fn is_closed(&self) -> bool {
        self.inner.is_closed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::admission::{BlockWithTimeout, RejectWhenFull};
    use crate::coordinator::request::Priority;
    use crate::coordinator::scheduler::{LeastLoaded, RoundRobin};
    use crate::image::{generate, Interpolator};
    use crate::runtime::MockEngine;
    use std::path::PathBuf;

    fn manifest() -> Manifest {
        Manifest::parse(
            r#"{
              "version": 1,
              "artifacts": [
                {"name": "bl_s2_b4", "kernel": "bilinear", "src": [16, 16],
                 "scale": 2, "batch": 4, "tile": [4, 32], "path": "x"},
                {"name": "nn_s4_b2", "kernel": "nearest", "src": [16, 16],
                 "scale": 4, "batch": 2, "tile": [4, 32], "path": "x"}
              ]
            }"#,
            PathBuf::from("."),
        )
        .unwrap()
    }

    fn cfg() -> ServingConfig {
        ServingConfig {
            workers: 2,
            batch_max: Some(4),
            batch_deadline_ms: 2.0,
            queue_cap: 64,
            ..ServingConfig::default()
        }
    }

    fn start(backend: Arc<dyn ResizeBackend>) -> Fleet {
        let m = manifest();
        FleetBuilder::new(&cfg(), &m)
            .backend(backend, TilePolicy::PortableFallback)
            .admission(BlockWithTimeout(Duration::from_secs(10)))
            .build()
            .unwrap()
    }

    fn req(kernel: Interpolator, img: crate::image::Image<f32>, scale: u32) -> Request {
        Request::new(kernel, img, scale)
    }

    #[test]
    fn end_to_end_requests_complete_correctly() {
        let svc = start(Arc::new(MockEngine::new()));
        let img = generate::test_scene(16, 16, 9);
        let want = crate::image::bilinear(&img, 2);
        let tickets: Vec<_> = (0..20)
            .map(|_| svc.submit(req(Interpolator::Bilinear, img.clone(), 2)).unwrap())
            .collect();
        for t in tickets {
            let out = t.wait().unwrap();
            assert_eq!(out.width(), 32);
            assert!(out.max_abs_diff(&want) < 1e-6);
        }
        let stats = svc.shutdown();
        assert_eq!(stats.completed.get(), 20);
        assert_eq!(stats.failed.get(), 0);
        assert!(stats.batches.get() <= 20);
        assert!(stats.mean_batch() >= 1.0);
        assert_eq!(
            stats.latency_by_class[Priority::Interactive.index()].count(),
            20
        );
    }

    #[test]
    fn unsupported_shape_rejected_fast() {
        let svc = start(Arc::new(MockEngine::new()));
        let img = generate::gradient(9, 9);
        match svc.submit(req(Interpolator::Bilinear, img, 2)) {
            Err(SubmitError::Unsupported) => {}
            other => panic!("expected Unsupported, got {other:?}"),
        }
        let img16 = generate::gradient(16, 16);
        assert!(matches!(
            svc.submit(req(Interpolator::Bicubic, img16, 2)),
            Err(SubmitError::Unsupported)
        ));
        let stats = svc.shutdown();
        assert_eq!(stats.rejected.get(), 2);
    }

    #[test]
    fn mixed_kernels_route_independently() {
        let svc = start(Arc::new(MockEngine::new()));
        let img = generate::test_scene(16, 16, 2);
        let t1 = svc.submit(req(Interpolator::Bilinear, img.clone(), 2)).unwrap();
        let t2 = svc.submit(req(Interpolator::Nearest, img.clone(), 4)).unwrap();
        assert_eq!(t1.wait().unwrap().width(), 32);
        assert_eq!(t2.wait().unwrap().width(), 64);
    }

    #[test]
    fn deadline_flushes_partial_batches() {
        // One request with batch_max 4: only the deadline can flush it.
        let svc = start(Arc::new(MockEngine::new()));
        let img = generate::test_scene(16, 16, 4);
        let t = svc.submit(req(Interpolator::Bilinear, img, 2)).expect("admitted");
        let out = t.wait().unwrap();
        assert_eq!(out.height(), 32);
    }

    #[test]
    fn zero_deadline_fails_fast() {
        let svc = start(Arc::new(MockEngine::new()));
        let img = generate::test_scene(16, 16, 4);
        let r = req(Interpolator::Bilinear, img, 2).deadline(Duration::ZERO);
        assert!(matches!(
            svc.submit(r),
            Err(SubmitError::DeadlineExceeded)
        ));
        let stats = svc.shutdown();
        assert_eq!(stats.shed.get(), 1);
        assert_eq!(stats.completed.get(), 0);
    }

    #[test]
    fn backend_failures_reported_per_request() {
        let svc = start(Arc::new(MockEngine::failing_every(1)));
        let img = generate::test_scene(16, 16, 5);
        let t = svc.submit(req(Interpolator::Bilinear, img, 2)).unwrap();
        assert!(t.wait().is_err());
        let stats = svc.shutdown();
        assert_eq!(stats.failed.get(), 1);
    }

    #[test]
    fn backpressure_saturates() {
        // Slow backend + tiny queue + non-blocking admission: Saturated.
        let slow = MockEngine::with_delay(Duration::from_millis(30));
        let m = manifest();
        let small = ServingConfig {
            workers: 1,
            batch_max: Some(1),
            batch_deadline_ms: 0.1,
            queue_cap: 2,
            ..ServingConfig::default()
        };
        let svc = FleetBuilder::new(&small, &m)
            .backend(Arc::new(slow), TilePolicy::PortableFallback)
            .admission(RejectWhenFull)
            .build()
            .unwrap();
        let img = generate::test_scene(16, 16, 6);
        let mut saturated = false;
        let mut tickets = Vec::new();
        for _ in 0..64 {
            match svc.submit(req(Interpolator::Bilinear, img.clone(), 2)) {
                Ok(t) => tickets.push(t),
                Err(SubmitError::Saturated) => {
                    saturated = true;
                    break;
                }
                Err(e) => panic!("unexpected {e:?}"),
            }
        }
        assert!(saturated, "queue should saturate under a slow backend");
        for t in tickets {
            let _ = t.wait();
        }
        let stats = svc.shutdown();
        assert!(stats.rejected.get() >= 1);
    }

    #[test]
    fn shutdown_drains_pending() {
        let svc = start(Arc::new(MockEngine::new()));
        let img = generate::test_scene(16, 16, 7);
        let tickets: Vec<_> = (0..10)
            .map(|_| svc.submit(req(Interpolator::Bilinear, img.clone(), 2)).unwrap())
            .collect();
        let stats = svc.shutdown(); // must drain, not drop
        assert_eq!(stats.completed.get() + stats.failed.get(), 10);
        for t in tickets {
            let _ = t.wait(); // all replies delivered
        }
    }

    #[test]
    fn two_member_fleet_round_robin_spreads_load() {
        let m = manifest();
        let svc = FleetBuilder::new(&cfg(), &m)
            .device(
                crate::device::find_device("gtx260").unwrap(),
                Arc::new(MockEngine::new()),
                TilePolicy::PortableFallback,
            )
            .device(
                crate::device::find_device("fermi").unwrap(),
                Arc::new(MockEngine::new()),
                TilePolicy::PortableFallback,
            )
            .scheduler(RoundRobin::default())
            .admission(BlockWithTimeout(Duration::from_secs(10)))
            .build()
            .unwrap();
        assert_eq!(svc.member_count(), 2);
        let img = generate::test_scene(16, 16, 8);
        let tickets: Vec<_> = (0..12)
            .map(|_| svc.submit(req(Interpolator::Bilinear, img.clone(), 2)).unwrap())
            .collect();
        let mut per_dev: HashMap<String, usize> = HashMap::new();
        for t in &tickets {
            *per_dev
                .entry(t.device_id().unwrap().to_string())
                .or_default() += 1;
        }
        assert_eq!(per_dev.get("gtx260"), Some(&6));
        assert_eq!(per_dev.get("fermi"), Some(&6));
        for t in tickets {
            t.wait().unwrap();
        }
        let views_completed: u64 = svc.members().iter().map(|v| v.stats.completed.get()).sum();
        assert_eq!(views_completed, 12);
        let stats = svc.shutdown();
        assert_eq!(stats.completed.get(), 12);
        assert!(stats.sim_cost_ns.get() > 0, "named members meter sim cost");
    }

    #[test]
    fn per_member_batch_max_derives_from_capability() {
        let m = manifest();
        let auto = ServingConfig {
            workers: 1,
            batch_max: None,
            ..ServingConfig::default()
        };
        let svc = FleetBuilder::new(&auto, &m)
            .device(
                crate::device::find_device("8800gts").unwrap(), // cc1.0
                Arc::new(MockEngine::new()),
                TilePolicy::PortableFallback,
            )
            .device(
                crate::device::find_device("fermi").unwrap(), // cc2.0
                Arc::new(MockEngine::new()),
                TilePolicy::PortableFallback,
            )
            .backend(Arc::new(MockEngine::new()), TilePolicy::PortableFallback)
            .build()
            .unwrap();
        let caps: Vec<usize> = svc.members().iter().map(|v| v.batch_max).collect();
        assert_eq!(caps, vec![4, 16, crate::coordinator::ANON_BATCH_MAX]);
        svc.shutdown();
        // The override pins everyone.
        let pinned = ServingConfig {
            workers: 1,
            batch_max: Some(2),
            ..ServingConfig::default()
        };
        let svc = FleetBuilder::new(&pinned, &m)
            .device(
                crate::device::find_device("fermi").unwrap(),
                Arc::new(MockEngine::new()),
                TilePolicy::PortableFallback,
            )
            .build()
            .unwrap();
        assert_eq!(svc.members()[0].batch_max, 2);
        svc.shutdown();
    }

    #[test]
    fn infeasible_deadline_declined_by_cost_eta_only() {
        use crate::coordinator::scheduler::CostModelEta;
        let m = manifest();
        let build = |cost_eta: bool| {
            let b = FleetBuilder::new(&cfg(), &m).device(
                crate::device::find_device("gtx260").unwrap(),
                Arc::new(MockEngine::new()),
                TilePolicy::PortableFallback,
            );
            let b = if cost_eta {
                b.scheduler(CostModelEta)
            } else {
                b.scheduler(RoundRobin::default())
            };
            b.admission(BlockWithTimeout(Duration::from_secs(10)))
                .build()
                .unwrap()
        };
        // cost-eta knows the per-request sim cost: a 1ns budget is
        // provably unmeetable and is declined up front.
        let svc = build(true);
        let img = generate::test_scene(16, 16, 11);
        let r = req(Interpolator::Bilinear, img.clone(), 2).deadline(Duration::from_nanos(1));
        assert!(matches!(svc.submit(r), Err(SubmitError::Infeasible)));
        // ...while an unpriced request and a generous budget still flow.
        let ok = svc
            .submit(req(Interpolator::Bilinear, img.clone(), 2).deadline(Duration::from_secs(5)))
            .unwrap();
        ok.wait().unwrap();
        let stats = svc.shutdown();
        assert_eq!(stats.infeasible.get(), 1);
        assert_eq!(stats.shed.get(), 0, "declined, not shed");
        // round-robin has no cost information: the same doomed budget is
        // admitted and shed later by the pipeline instead.
        let svc = build(false);
        let r = req(Interpolator::Bilinear, img, 2).deadline(Duration::from_nanos(1));
        match svc.submit(r) {
            Ok(t) => {
                let _ = t.wait();
            }
            Err(SubmitError::DeadlineExceeded) => {}
            Err(e) => panic!("unexpected {e:?}"),
        }
        let stats = svc.shutdown();
        assert_eq!(stats.infeasible.get(), 0);
    }

    #[test]
    fn retune_hot_swaps_tile_without_draining() {
        use crate::autotuner::{portable_over, DeviceTuning, TunedPoint};
        let fast = |tile: TileDim, other: TileDim| {
            let dt = DeviceTuning::from_points(
                "gtx260".to_string(),
                vec![
                    TunedPoint { tile, ms: 1.0 },
                    TunedPoint {
                        tile: other,
                        ms: 2.0,
                    },
                ],
                2,
            )
            .unwrap();
            let per_device = vec![dt];
            TuningOutcome {
                kernel: Interpolator::Bilinear,
                scale: 2,
                src: (16, 16),
                strategy: "test".to_string(),
                evaluations: 2,
                portable: portable_over(&per_device),
                per_device,
            }
        };
        let m = Manifest::parse(
            r#"{
              "version": 1,
              "artifacts": [
                {"name": "a", "kernel": "bilinear", "src": [16, 16],
                 "scale": 2, "batch": 4, "tile": [4, 32], "path": "x"},
                {"name": "b", "kernel": "bilinear", "src": [16, 16],
                 "scale": 2, "batch": 4, "tile": [8, 8], "path": "x"}
              ]
            }"#,
            PathBuf::from("."),
        )
        .unwrap();
        let t32x4 = TileDim::new(32, 4);
        let t8x8 = TileDim::new(8, 8);
        let svc = FleetBuilder::new(&cfg(), &m)
            .device(
                crate::device::find_device("gtx260").unwrap(),
                Arc::new(MockEngine::new()),
                TilePolicy::PerDevice(fast(t32x4, t8x8)),
            )
            .admission(BlockWithTimeout(Duration::from_secs(10)))
            .build()
            .unwrap();
        let ctl = svc.controller();
        assert_eq!(svc.members()[0].tile_pref, Some(t32x4));
        let img = generate::test_scene(16, 16, 12);
        // Keep traffic flowing across the swap: no drain, no rebuild.
        let before = svc
            .submit(req(Interpolator::Bilinear, img.clone(), 2))
            .unwrap();
        let v_before = svc.plan_metrics().version;
        let tile = ctl.retune("gtx260", &fast(t8x8, t32x4)).unwrap();
        assert_eq!(tile, Some(t8x8));
        assert_eq!(svc.members()[0].tile_pref, Some(t8x8));
        assert!(
            svc.plan_metrics().version > v_before,
            "retune republishes: once it returns, no submitter routes the stale tile"
        );
        let after = svc
            .submit(req(Interpolator::Bilinear, img, 2))
            .unwrap();
        before.wait().unwrap();
        after.wait().unwrap();
        assert!(ctl.retune("ghost", &fast(t8x8, t32x4)).is_err());
        let stats = svc.shutdown();
        assert_eq!(stats.retunes.get(), 1);
        assert_eq!(stats.completed.get(), 2);
        // Control commands error once the fleet is gone.
        assert!(ctl.retune("gtx260", &fast(t8x8, t32x4)).is_err());
        assert!(ctl.is_closed());
    }

    #[test]
    fn builder_rejects_bad_config_and_empty_fleet() {
        let m = manifest();
        let bad = ServingConfig {
            workers: 0,
            ..ServingConfig::default()
        };
        let err = FleetBuilder::new(&bad, &m)
            .backend(Arc::new(MockEngine::new()), TilePolicy::PortableFallback)
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("invalid serving configuration"), "{err}");
        assert!(FleetBuilder::new(&cfg(), &m).build().is_err(), "no members");
    }

    // ------------------------------------------------- control plane --

    #[test]
    fn add_member_joins_the_live_fleet() {
        let m = manifest();
        let svc = FleetBuilder::new(&cfg(), &m)
            .device(
                crate::device::find_device("gtx260").unwrap(),
                Arc::new(MockEngine::new()),
                TilePolicy::PortableFallback,
            )
            .scheduler(RoundRobin::default())
            .admission(BlockWithTimeout(Duration::from_secs(10)))
            .build()
            .unwrap();
        let ctl = svc.controller();
        let epoch0 = ctl.epoch();
        assert_eq!(svc.member_count(), 1);
        let img = generate::test_scene(16, 16, 31);
        svc.submit(req(Interpolator::Bilinear, img.clone(), 2))
            .unwrap()
            .wait()
            .unwrap();
        ctl.add_member(
            crate::device::find_device("fermi").unwrap(),
            Arc::new(MockEngine::new()),
            TilePolicy::PortableFallback,
        )
        .unwrap();
        assert_eq!(svc.member_count(), 2);
        assert!(ctl.epoch() > epoch0, "membership change bumps the epoch");
        // Round-robin now spreads across both members.
        let tickets: Vec<_> = (0..8)
            .map(|_| svc.submit(req(Interpolator::Bilinear, img.clone(), 2)).unwrap())
            .collect();
        let mut devs: Vec<&str> = tickets.iter().filter_map(|t| t.device_id()).collect();
        devs.sort();
        devs.dedup();
        assert_eq!(devs, vec!["fermi", "gtx260"]);
        for t in tickets {
            t.wait().unwrap();
        }
        let stats = svc.shutdown();
        assert_eq!(stats.completed.get(), 9);
    }

    #[test]
    fn remove_member_graceful_completes_queued_work() {
        let m = manifest();
        let svc = FleetBuilder::new(&cfg(), &m)
            .device(
                crate::device::find_device("gtx260").unwrap(),
                Arc::new(MockEngine::new()),
                TilePolicy::PortableFallback,
            )
            .device(
                crate::device::find_device("fermi").unwrap(),
                Arc::new(MockEngine::new()),
                TilePolicy::PortableFallback,
            )
            .scheduler(RoundRobin::default())
            .admission(BlockWithTimeout(Duration::from_secs(10)))
            .build()
            .unwrap();
        let ctl = svc.controller();
        let img = generate::test_scene(16, 16, 32);
        let tickets: Vec<_> = (0..10)
            .map(|_| svc.submit(req(Interpolator::Bilinear, img.clone(), 2)).unwrap())
            .collect();
        ctl.remove_member("fermi", DrainMode::Graceful).unwrap();
        assert_eq!(svc.member_count(), 1);
        assert!(ctl.remove_member("fermi", DrainMode::Graceful).is_err());
        for t in tickets {
            t.wait().unwrap(); // nothing lost across the epoch flip
        }
        // New work still flows, all onto the survivor.
        let t = svc.submit(req(Interpolator::Bilinear, img, 2)).unwrap();
        assert_eq!(t.device_id(), Some("gtx260"));
        t.wait().unwrap();
        let stats = svc.shutdown();
        assert_eq!(
            stats.completed.get(),
            11,
            "removed member's stats are retained in fleet totals"
        );
        assert_eq!(stats.failed.get(), 0);
    }

    #[test]
    fn remove_member_immediate_sheds_queued_work() {
        let m = manifest();
        let slow = ServingConfig {
            workers: 1,
            batch_max: Some(1),
            batch_deadline_ms: 0.1,
            queue_cap: 64,
            work_stealing: false,
            ..ServingConfig::default()
        };
        let svc = FleetBuilder::new(&slow, &m)
            .device(
                crate::device::find_device("gtx260").unwrap(),
                Arc::new(MockEngine::with_delay(Duration::from_millis(20))),
                TilePolicy::PortableFallback,
            )
            .admission(BlockWithTimeout(Duration::from_secs(10)))
            .build()
            .unwrap();
        let ctl = svc.controller();
        let img = generate::test_scene(16, 16, 33);
        let tickets: Vec<_> = (0..8)
            .map(|_| svc.submit(req(Interpolator::Bilinear, img.clone(), 2)).unwrap())
            .collect();
        ctl.remove_member("gtx260", DrainMode::Immediate).unwrap();
        let mut answered = 0;
        for t in tickets {
            match t.wait() {
                Ok(_) => answered += 1,
                Err(e) => {
                    answered += 1;
                    let msg = e.to_string();
                    assert!(
                        msg.contains("removed") || msg.contains("shut down"),
                        "unexpected error: {msg}"
                    );
                }
            }
        }
        assert_eq!(answered, 8, "every ticket resolves, none hang");
        let stats = svc.shutdown();
        assert_eq!(stats.completed.get() + stats.failed.get(), 8);
    }

    #[test]
    fn drain_stops_new_work_but_keeps_member() {
        let m = manifest();
        let svc = FleetBuilder::new(&cfg(), &m)
            .device(
                crate::device::find_device("gtx260").unwrap(),
                Arc::new(MockEngine::new()),
                TilePolicy::PortableFallback,
            )
            .device(
                crate::device::find_device("fermi").unwrap(),
                Arc::new(MockEngine::new()),
                TilePolicy::PortableFallback,
            )
            .scheduler(RoundRobin::default())
            .admission(BlockWithTimeout(Duration::from_secs(10)))
            .build()
            .unwrap();
        let ctl = svc.controller();
        let epoch0 = ctl.epoch();
        ctl.drain("gtx260").unwrap();
        assert!(ctl.drain("ghost").is_err());
        assert!(ctl.epoch() > epoch0);
        let topo = ctl.topology();
        assert_eq!(topo.members.len(), 2, "drained member stays registered");
        assert!(topo.members.iter().any(|v| &*v.label == "gtx260" && v.draining));
        let img = generate::test_scene(16, 16, 34);
        for _ in 0..6 {
            let t = svc.submit(req(Interpolator::Bilinear, img.clone(), 2)).unwrap();
            assert_eq!(t.device_id(), Some("fermi"), "drained member takes no new work");
            t.wait().unwrap();
        }
        svc.shutdown();
    }

    #[test]
    fn set_scheduler_and_admission_swap_live() {
        let m = manifest();
        let svc = FleetBuilder::new(&cfg(), &m)
            .device(
                crate::device::find_device("gtx260").unwrap(),
                Arc::new(MockEngine::new()),
                TilePolicy::PortableFallback,
            )
            .scheduler(RoundRobin::default())
            .admission(BlockWithTimeout(Duration::from_secs(10)))
            .build()
            .unwrap();
        let ctl = svc.controller();
        assert_eq!(svc.scheduler_name(), "round-robin");
        ctl.set_scheduler(LeastLoaded).unwrap();
        assert_eq!(svc.scheduler_name(), "least-loaded");
        ctl.set_scheduler_by_name("cost-eta").unwrap();
        assert_eq!(svc.scheduler_name(), "cost-eta");
        assert!(ctl.set_scheduler_by_name("nope").is_err());
        ctl.set_admission_by_name("reject", Duration::from_secs(1))
            .unwrap();
        assert_eq!(svc.admission_name(), "reject");
        ctl.set_admission(BlockWithTimeout(Duration::from_secs(1)))
            .unwrap();
        assert_eq!(svc.admission_name(), "block");
        // The swapped-in scheduler serves traffic.
        let img = generate::test_scene(16, 16, 35);
        svc.submit(req(Interpolator::Bilinear, img, 2))
            .unwrap()
            .wait()
            .unwrap();
        svc.shutdown();
    }

    #[test]
    fn set_steal_config_validates_and_applies() {
        let m = manifest();
        let svc = FleetBuilder::new(&cfg(), &m)
            .backend(Arc::new(MockEngine::new()), TilePolicy::PortableFallback)
            .build()
            .unwrap();
        let ctl = svc.controller();
        assert!(ctl.set_steal_config(true, 0).is_err());
        ctl.set_steal_config(false, 7).unwrap();
        ctl.set_steal_config(true, 2).unwrap();
        svc.shutdown();
        assert!(ctl.set_steal_config(true, 2).is_err(), "closed fleet");
    }

    #[test]
    fn topology_reports_epoch_and_members() {
        let m = manifest();
        let svc = FleetBuilder::new(&cfg(), &m)
            .device(
                crate::device::find_device("gtx260").unwrap(),
                Arc::new(MockEngine::new()),
                TilePolicy::PortableFallback,
            )
            .backend(Arc::new(MockEngine::new()), TilePolicy::PortableFallback)
            .build()
            .unwrap();
        let ctl = svc.controller();
        let topo = ctl.topology();
        assert_eq!(topo.epoch, 2, "one epoch per registered member");
        assert_eq!(topo.members.len(), 2);
        assert_eq!(&*topo.members[0].label, "gtx260");
        assert!(
            topo.members[1].label.starts_with("dev"),
            "anonymous members get a devN label"
        );
        assert_ne!(topo.members[0].id, topo.members[1].id);
        assert!(topo.members.iter().all(|v| !v.draining));
        svc.shutdown();
    }

    // --------------------------------------------------- submit plan --

    #[test]
    fn steady_state_submit_is_lock_and_alloc_free_on_the_plan() {
        // The acceptance criterion for the lock-free hot path, phrased
        // over the plan instrumentation: after one warmup submit primes
        // this thread's cache, N submits advance ONLY `fast_hits` —
        // zero plan refreshes (the plan RwLock was never read), zero
        // rebuilds, zero snapshot-buffer growth (no allocation).
        let svc = start(Arc::new(MockEngine::new()));
        let img = generate::test_scene(16, 16, 41);
        svc.submit(req(Interpolator::Bilinear, img.clone(), 2))
            .unwrap()
            .wait()
            .unwrap();
        let m0 = svc.plan_metrics();
        let tickets: Vec<_> = (0..100)
            .map(|_| svc.submit(req(Interpolator::Bilinear, img.clone(), 2)).unwrap())
            .collect();
        let m1 = svc.plan_metrics();
        assert_eq!(m1.fast_hits, m0.fast_hits + 100, "every submit hit the cache");
        assert_eq!(m1.refreshes, m0.refreshes, "plan RwLock untouched");
        assert_eq!(m1.rebuilds, m0.rebuilds, "no control-plane churn");
        assert_eq!(m1.buf_grows, m0.buf_grows, "snapshot buffer reused, no alloc");
        assert_eq!(m1.version, m0.version);
        for t in tickets {
            t.wait().unwrap();
        }
        svc.shutdown();
    }

    #[test]
    fn control_plane_mutations_republish_the_plan() {
        let m = manifest();
        let svc = FleetBuilder::new(&cfg(), &m)
            .device(
                crate::device::find_device("gtx260").unwrap(),
                Arc::new(MockEngine::new()),
                TilePolicy::PortableFallback,
            )
            .device(
                crate::device::find_device("fermi").unwrap(),
                Arc::new(MockEngine::new()),
                TilePolicy::PortableFallback,
            )
            .scheduler(RoundRobin::default())
            .admission(BlockWithTimeout(Duration::from_secs(10)))
            .build()
            .unwrap();
        let ctl = svc.controller();
        let v0 = svc.plan_metrics().version;
        assert_eq!(v0, 2, "one rebuild per registered member");
        ctl.set_scheduler(LeastLoaded).unwrap();
        assert_eq!(svc.plan_metrics().version, v0 + 1);
        ctl.set_admission_by_name("reject", Duration::from_secs(1))
            .unwrap();
        assert_eq!(svc.plan_metrics().version, v0 + 2);
        ctl.set_steal_config(true, 2).unwrap();
        assert_eq!(svc.plan_metrics().version, v0 + 3);
        // Drain republishes WITHOUT the drained member: the very next
        // submit — same thread, no sleep — must route around it.
        ctl.drain("gtx260").unwrap();
        assert_eq!(svc.plan_metrics().version, v0 + 4);
        let img = generate::test_scene(16, 16, 42);
        let t = svc.submit(req(Interpolator::Bilinear, img.clone(), 2)).unwrap();
        assert_eq!(t.device_id(), Some("fermi"), "drained member unpublished");
        t.wait().unwrap();
        ctl.remove_member("gtx260", DrainMode::Graceful).unwrap();
        assert_eq!(svc.plan_metrics().version, v0 + 5);
        let t = svc.submit(req(Interpolator::Bilinear, img, 2)).unwrap();
        assert_eq!(t.device_id(), Some("fermi"));
        t.wait().unwrap();
        svc.shutdown();
    }
}
