//! The service façade: a fleet of device members — each with its own
//! router (tuned tile), admission queue, batcher thread, and worker
//! pool — behind one typed submit path. A [`Scheduler`] picks the member
//! per request; an [`AdmissionPolicy`] decides what a full queue means.
//!
//! Build one with [`ServiceBuilder`]:
//!
//! ```no_run
//! # use std::sync::Arc;
//! # use tilekit::config::ServingConfig;
//! # use tilekit::coordinator::{LeastLoaded, Request, ServiceBuilder, TilePolicy};
//! # use tilekit::device::find_device;
//! # use tilekit::image::{generate, Interpolator};
//! # use tilekit::runtime::{Manifest, MockEngine};
//! # let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
//! # let outcome = tilekit::autotuner::TuningSession::sim().run()?;
//! let svc = ServiceBuilder::new(&ServingConfig::default(), &manifest)
//!     .device(
//!         find_device("gtx260").unwrap(),
//!         Arc::new(MockEngine::new()),
//!         TilePolicy::PerDevice(outcome.clone()),
//!     )
//!     .device(
//!         find_device("fermi").unwrap(),
//!         Arc::new(MockEngine::new()),
//!         TilePolicy::PerDevice(outcome),
//!     )
//!     .scheduler(LeastLoaded)
//!     .build()?;
//! let ticket = svc.submit(Request::new(
//!     Interpolator::Bilinear,
//!     generate::gradient(64, 64),
//!     2,
//! ))?;
//! let _img = ticket.wait()?;
//! # Ok::<(), anyhow::Error>(())
//! ```

use super::admission::{admission_by_name, AdmissionPolicy};
use super::batcher::{Batch, BatcherState, Shed};
use super::request::{Request, RequestKey, ResizeRequest, Ticket};
use super::router::{Router, SharedRouter, TilePolicy};
use super::scheduler::{scheduler_by_name, CostMeter, DeviceSnapshot, Scheduler};
use super::stats::{IdGen, ServingStats};
use super::stealing::{select_steals, StealPolicy};
use super::worker::spawn_workers;
use crate::autotuner::{CostModel, SimCostModel, TuningOutcome};
use crate::config::ServingConfig;
use crate::device::DeviceDescriptor;
use crate::exec::{bounded, Receiver, Sender};
use crate::runtime::{Manifest, ResizeBackend};
use crate::tiling::TileDim;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::sync::{Arc, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Cap on the batcher's poll interval while requests are pending, so
/// cancellations and expired deadlines are shed promptly even when the
/// batch deadline is long.
const SHED_POLL: Duration = Duration::from_millis(5);

/// Idle-poll interval of a batcher that may steal, used only while a
/// peer is actually over the steal threshold — a quiet fleet stays on
/// the slow 50ms idle tick.
const STEAL_POLL: Duration = Duration::from_millis(2);

/// Dynamic-batch cap for members with no device identity and no
/// explicit `batch_max` override (the classic single-backend default).
pub const ANON_BATCH_MAX: usize = 8;

/// Why a submission was not admitted.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Admission queue full (or the admission timeout elapsed) — retry
    /// later (backpressure).
    Saturated,
    /// No member's artifact set can serve this (kernel, size, scale).
    Unsupported,
    /// The request's latency budget is already spent.
    DeadlineExceeded,
    /// The deadline budget is below the best queue-depth-aware ETA any
    /// member offers: no device can meet it, so the service declines up
    /// front instead of accepting work it would shed later.
    Infeasible,
    /// Service is shutting down.
    ShuttingDown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Saturated => write!(f, "admission queue saturated"),
            SubmitError::Unsupported => write!(f, "no device serves this request shape"),
            SubmitError::DeadlineExceeded => write!(f, "deadline exceeded"),
            SubmitError::Infeasible => {
                write!(f, "no device can meet the deadline budget at current load")
            }
            SubmitError::ShuttingDown => write!(f, "service shutting down"),
        }
    }
}
impl std::error::Error for SubmitError {}

/// One registered fleet member before startup.
struct MemberSpec {
    device: Option<DeviceDescriptor>,
    backend: Arc<dyn ResizeBackend>,
    policy: TilePolicy,
    manifest: Option<Manifest>,
}

/// A running fleet member: its own router, admission queue, batcher, and
/// worker pool.
struct Member {
    /// Shared with every ticket scheduled onto this member.
    label: Arc<str>,
    device: Option<DeviceDescriptor>,
    /// Hot-swappable routing table ([`Service::retune`] replaces the
    /// inner router while the pipeline keeps serving).
    router: SharedRouter,
    /// The manifest the router routes over, kept (shared, not copied)
    /// for retune rebuilds.
    manifest: Arc<Manifest>,
    stats: Arc<ServingStats>,
    /// Sim-cost oracle for this device (None for anonymous members).
    meter: Option<Arc<CostMeter>>,
    /// Cost-model estimate (ms/request) per supported key, for the
    /// scheduler's ETA computation; refreshed by retune. Empty for
    /// anonymous members.
    cost: Arc<RwLock<HashMap<RequestKey, f64>>>,
    /// This member's dynamic-batch cap (capability-derived unless the
    /// config overrides it).
    batch_max: usize,
    /// Requests this member executes concurrently (workers × batch
    /// cap); the scheduler's ETA estimates divide the backlog by it.
    slots: u64,
    admit_tx: Option<Sender<ResizeRequest>>,
    batcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

/// Read-only view of one member for reporting (`tilekit serve`'s
/// per-device breakdown, tests).
pub struct MemberView<'a> {
    /// Device id, or a synthetic `devN` label for anonymous members.
    pub label: &'a str,
    /// The device descriptor, when the member has an identity.
    pub device: Option<&'a DeviceDescriptor>,
    /// The tile this member's router currently prefers.
    pub tile_pref: Option<TileDim>,
    /// The member's dynamic-batch cap (capability-derived unless the
    /// config overrides it).
    pub batch_max: usize,
    /// This member's serving stats.
    pub stats: &'a Arc<ServingStats>,
    /// Snapshot of this member's current routing table (a retune after
    /// this call is not reflected).
    pub router: Arc<Router>,
}

/// A peer's steal surface, shared with every other member's batcher: the
/// peer's admission queue (to take work from) and its stats (to record
/// the transfer on the victim side).
struct StealPeer {
    queue: Receiver<ResizeRequest>,
    stats: Arc<ServingStats>,
}

/// Everything a member's batcher thread needs beyond its own queues.
struct BatcherConfig {
    batch_max: usize,
    deadline: Duration,
    /// `Some` when this member may steal from `peers` while idle.
    steal: Option<StealPolicy>,
    peers: Vec<StealPeer>,
}

/// The scheduler's ETA table: the cost-model estimate (ms) of ONE
/// request per supported key, through the variant `router` prefers.
fn cost_table(router: &Router, meter: Option<&CostMeter>) -> HashMap<RequestKey, f64> {
    let mut cost = HashMap::new();
    if let Some(m) = meter {
        for key in router.keys() {
            if let Ok(entry) = router.route(&key, 1) {
                let ms = m.ms_of(entry);
                if ms.is_finite() {
                    cost.insert(key, ms);
                }
            }
        }
    }
    cost
}

/// Builder for a [`Service`]. Register one or more members, then
/// [`build`](ServiceBuilder::build).
pub struct ServiceBuilder {
    cfg: ServingConfig,
    manifest: Manifest,
    members: Vec<MemberSpec>,
    scheduler: Option<Box<dyn Scheduler>>,
    admission: Option<Box<dyn AdmissionPolicy>>,
    cost_model: Arc<dyn CostModel + Send + Sync>,
}

impl ServiceBuilder {
    /// Start a builder over a shared artifact manifest. The config's
    /// `scheduler` / `admission` names supply the defaults (overridable
    /// with [`scheduler`](Self::scheduler) / [`admission`](Self::admission)).
    pub fn new(cfg: &ServingConfig, manifest: &Manifest) -> ServiceBuilder {
        ServiceBuilder {
            cfg: cfg.clone(),
            manifest: manifest.clone(),
            members: Vec::new(),
            scheduler: None,
            admission: None,
            cost_model: Arc::new(SimCostModel),
        }
    }

    /// Register a device member: its descriptor (identity + sim
    /// parameters), the backend executing its batches, and the tile
    /// policy its router resolves through (`TilePolicy::PerDevice`
    /// routes it to its tuned tile).
    pub fn device(
        mut self,
        device: DeviceDescriptor,
        backend: Arc<dyn ResizeBackend>,
        policy: TilePolicy,
    ) -> ServiceBuilder {
        self.members.push(MemberSpec {
            device: Some(device),
            backend,
            policy,
            manifest: None,
        });
        self
    }

    /// Register a device member serving its own manifest instead of the
    /// shared one (heterogeneous artifact sets).
    pub fn device_with_manifest(
        mut self,
        device: DeviceDescriptor,
        backend: Arc<dyn ResizeBackend>,
        policy: TilePolicy,
        manifest: Manifest,
    ) -> ServiceBuilder {
        self.members.push(MemberSpec {
            device: Some(device),
            backend,
            policy,
            manifest: Some(manifest),
        });
        self
    }

    /// Register an anonymous single-backend member (no device identity;
    /// no per-device tuning or cost estimates). This is the classic
    /// one-backend deployment.
    pub fn backend(
        mut self,
        backend: Arc<dyn ResizeBackend>,
        policy: TilePolicy,
    ) -> ServiceBuilder {
        self.members.push(MemberSpec {
            device: None,
            backend,
            policy,
            manifest: None,
        });
        self
    }

    /// Override the scheduler (default: the config's `scheduler` name).
    pub fn scheduler(mut self, s: impl Scheduler + 'static) -> ServiceBuilder {
        self.scheduler = Some(Box::new(s));
        self
    }

    /// Override the admission policy (default: the config's `admission`
    /// name with its `admission_timeout_ms`).
    pub fn admission(mut self, a: impl AdmissionPolicy + 'static) -> ServiceBuilder {
        self.admission = Some(Box::new(a));
        self
    }

    /// Replace the cost model behind ETA scheduling and sim-cost
    /// metering (default: the timing simulator).
    pub fn cost_model(mut self, m: impl CostModel + Send + Sync + 'static) -> ServiceBuilder {
        self.cost_model = Arc::new(m);
        self
    }

    /// Validate the config and start every member's pipeline.
    pub fn build(self) -> Result<Service> {
        self.cfg
            .validate()
            .context("invalid serving configuration")?;
        if self.members.is_empty() {
            bail!("service needs at least one device member");
        }
        let scheduler = match self.scheduler {
            Some(s) => s,
            None => scheduler_by_name(&self.cfg.scheduler)?,
        };
        let admission = match self.admission {
            Some(a) => a,
            None => admission_by_name(
                &self.cfg.admission,
                Duration::from_secs_f64(self.cfg.admission_timeout_ms / 1e3),
            )?,
        };
        // Phase 1: resolve every member's identity, router, cost table,
        // batch cap, and admission queue — so phase 2 can hand each
        // batcher a view of its peers' queues for work-stealing.
        let shared_manifest = Arc::new(self.manifest);
        let mut seeds = Vec::with_capacity(self.members.len());
        for (i, spec) in self.members.into_iter().enumerate() {
            let manifest = spec
                .manifest
                .map(Arc::new)
                .unwrap_or_else(|| Arc::clone(&shared_manifest));
            let label: Arc<str> = spec
                .device
                .as_ref()
                .map(|d| d.id.clone())
                .unwrap_or_else(|| format!("dev{i}"))
                .into();
            let device_id = spec.device.as_ref().map(|d| d.id.clone());
            let router = Router::for_device(&manifest, spec.policy, device_id.as_deref());
            let meter = spec
                .device
                .clone()
                .map(|d| Arc::new(CostMeter::new(d, Arc::clone(&self.cost_model))));
            let cost = cost_table(&router, meter.as_deref());
            let batch_max = self.cfg.batch_max_for(spec.device.as_ref());
            let (admit_tx, admit_rx) = bounded::<ResizeRequest>(self.cfg.queue_cap);
            seeds.push(MemberSeed {
                label,
                device: spec.device,
                manifest,
                router: router.into_shared(),
                backend: spec.backend,
                meter,
                cost: Arc::new(RwLock::new(cost)),
                stats: Arc::new(ServingStats::new()),
                batch_max,
                admit_tx,
                admit_rx,
            });
        }
        // Phase 2: wire each member to its peers and start the
        // pipelines. A single-member fleet has nobody to steal from.
        let steal_enabled = self.cfg.work_stealing && seeds.len() > 1;
        let peer_views: Vec<Vec<StealPeer>> = (0..seeds.len())
            .map(|i| {
                if !steal_enabled {
                    return Vec::new();
                }
                seeds
                    .iter()
                    .enumerate()
                    .filter(|(j, _)| *j != i)
                    .map(|(_, s)| StealPeer {
                        queue: s.admit_rx.clone(),
                        stats: Arc::clone(&s.stats),
                    })
                    .collect()
            })
            .collect();
        let members = seeds
            .into_iter()
            .zip(peer_views)
            .map(|(seed, peers)| start_member(&self.cfg, seed, peers))
            .collect();
        Ok(Service {
            members,
            scheduler,
            admission,
            local: Arc::new(ServingStats::new()),
            ids: IdGen::default(),
        })
    }
}

/// One member after phase-1 resolution, before its threads start.
struct MemberSeed {
    label: Arc<str>,
    device: Option<DeviceDescriptor>,
    manifest: Arc<Manifest>,
    router: SharedRouter,
    backend: Arc<dyn ResizeBackend>,
    meter: Option<Arc<CostMeter>>,
    cost: Arc<RwLock<HashMap<RequestKey, f64>>>,
    stats: Arc<ServingStats>,
    batch_max: usize,
    admit_tx: Sender<ResizeRequest>,
    admit_rx: Receiver<ResizeRequest>,
}

/// Start one member's pipeline: admission queue → batcher thread →
/// worker pool (the old single-backend coordinator, one per device).
/// The batcher doubles as the member's work-stealing thief: whenever it
/// goes idle it may pull compatible pending requests from a hot peer.
fn start_member(cfg: &ServingConfig, seed: MemberSeed, peers: Vec<StealPeer>) -> Member {
    let MemberSeed {
        label,
        device,
        manifest,
        router,
        backend,
        meter,
        cost,
        stats,
        batch_max,
        admit_tx,
        admit_rx,
    } = seed;
    let (batch_tx, batch_rx) = bounded::<Batch>(cfg.queue_cap.max(4));

    let bcfg = BatcherConfig {
        batch_max,
        deadline: Duration::from_secs_f64(cfg.batch_deadline_ms / 1e3),
        steal: (!peers.is_empty()).then_some(StealPolicy {
            min_victim_backlog: cfg.steal_threshold,
            // Steal at most one batch's worth per attempt.
            max_per_attempt: batch_max,
        }),
        peers,
    };
    let batcher = {
        let stats = Arc::clone(&stats);
        let router = Arc::clone(&router);
        std::thread::Builder::new()
            .name(format!("tilekit-batcher-{label}"))
            .spawn(move || run_batcher(bcfg, admit_rx, batch_tx, stats, router))
            .expect("spawn batcher")
    };

    let workers = spawn_workers(
        cfg.workers,
        batch_rx,
        Arc::clone(&router),
        backend,
        Arc::clone(&stats),
        meter.clone(),
    );

    Member {
        label,
        device,
        router,
        manifest,
        stats,
        meter,
        cost,
        batch_max,
        slots: (cfg.workers.max(1) * batch_max) as u64,
        admit_tx: Some(admit_tx),
        batcher: Some(batcher),
        workers,
    }
}

/// The batcher thread body: drain admissions, group, shed
/// cancelled/expired, flush on size/deadline — and, when idle with
/// peers configured, steal compatible pending work from the hottest
/// peer queue over the threshold.
fn run_batcher(
    cfg: BatcherConfig,
    admit_rx: Receiver<ResizeRequest>,
    batch_tx: Sender<Batch>,
    stats: Arc<ServingStats>,
    router: SharedRouter,
) {
    let mut state = BatcherState::new(cfg.batch_max, cfg.deadline);
    // Adaptive idle poll: 50ms while the fleet is quiet, dropping to
    // STEAL_POLL only while some peer sits at/over the steal threshold
    // (re-checked on every idle tick).
    let mut peers_hot = false;
    loop {
        let timeout = match state.next_deadline(Instant::now()) {
            // While requests are pending, poll fast enough to shed
            // cancellations/deadlines promptly.
            Some(d) => d.min(SHED_POLL),
            None if peers_hot => STEAL_POLL,
            None => Duration::from_millis(50),
        };
        match admit_rx.recv_timeout(timeout) {
            Ok(Some(req)) => {
                if let Some(batch) = state.push(req) {
                    if batch_tx.send(batch).is_err() {
                        return; // workers gone
                    }
                }
            }
            Ok(None) => {
                // Timed out with an empty queue. If nothing is pending
                // locally either, this member is idle — try to steal.
                // Paced by our own unanswered backlog (under two
                // batches' worth): a thief must not hoard work faster
                // than it executes, only keep its own pipeline fed.
                // While the pacing gate blocks, the fast tick persists
                // on purpose: it is the pacing poll, bounded by our own
                // workers' drain time (a batch or two), and dropping to
                // the slow tick there would cap the steady-state steal
                // rate at one attempt per 50ms.
                if let Some(policy) = &cfg.steal {
                    peers_hot = cfg
                        .peers
                        .iter()
                        .any(|p| p.queue.len() >= policy.min_victim_backlog);
                    if peers_hot
                        && state.pending_len() == 0
                        && stats.inflight() < 2 * cfg.batch_max as u64
                    {
                        let (stole, batches) =
                            steal_from_peers(policy, &cfg.peers, &router, &stats, &mut state);
                        for batch in batches {
                            if batch_tx.send(batch).is_err() {
                                return;
                            }
                        }
                        // A deep peer whose work we cannot route (or
                        // that is all cancelled/expired) yields nothing;
                        // drop back to the slow idle tick instead of
                        // re-scanning its queue every STEAL_POLL.
                        if stole == 0 {
                            peers_hot = false;
                        }
                    }
                }
            }
            Err(_) => break, // admissions closed: shutdown
        }
        for (req, reason) in state.sweep(Instant::now()) {
            let (counter, msg) = match reason {
                Shed::Cancelled => (&stats.cancelled, "cancelled"),
                Shed::DeadlineExceeded => (&stats.shed, "deadline exceeded before execution"),
            };
            counter.inc();
            let _ = req
                .reply
                .send(Err(anyhow::anyhow!("request {} {msg}", req.id)));
        }
        for batch in state.flush_expired(Instant::now()) {
            if batch_tx.send(batch).is_err() {
                return;
            }
        }
    }
    // Shutdown: flush everything still pending.
    for batch in state.flush_all() {
        let _ = batch_tx.send(batch);
    }
}

/// One steal attempt by an idle member: pick the deepest peer queue at
/// or over the backlog threshold, take a compatible slice of its newest
/// requests (see [`select_steals`] for the invariants), account the
/// ownership transfer on both sides, and push the loot into the thief's
/// batcher state. Returns how many requests were stolen and any batches
/// the loot filled.
fn steal_from_peers(
    policy: &StealPolicy,
    peers: &[StealPeer],
    router: &SharedRouter,
    stats: &ServingStats,
    state: &mut BatcherState,
) -> (usize, Vec<Batch>) {
    let Some(victim) = peers
        .iter()
        .filter(|p| p.queue.len() >= policy.min_victim_backlog)
        .max_by_key(|p| p.queue.len())
    else {
        return (0, Vec::new());
    };
    let current = Arc::clone(&router.read().expect("router lock"));
    let now = Instant::now();
    let loot = victim.queue.steal_by(|q| {
        select_steals(q, |key| current.supports(key), now, policy.max_per_attempt)
    });
    let stole = loot.len();
    let mut batches = Vec::new();
    for req in loot {
        victim.stats.stolen.inc();
        stats.steals.inc();
        if let Some(batch) = state.push(req) {
            batches.push(batch);
        }
    }
    (stole, batches)
}

/// The running fleet-aware serving system.
pub struct Service {
    members: Vec<Member>,
    scheduler: Box<dyn Scheduler>,
    admission: Box<dyn AdmissionPolicy>,
    /// Submit-side counters (unsupported rejections, fail-fast deadline
    /// sheds) that belong to no single member.
    local: Arc<ServingStats>,
    ids: IdGen,
}

impl Service {
    /// Convenience: a single-member service over one backend (the old
    /// `Coordinator::start` deployment shape).
    pub fn single(
        cfg: &ServingConfig,
        manifest: &Manifest,
        backend: Arc<dyn ResizeBackend>,
        policy: TilePolicy,
    ) -> Result<Service> {
        ServiceBuilder::new(cfg, manifest)
            .backend(backend, policy)
            .build()
    }

    /// Submit a typed request. The scheduler picks the member, the
    /// admission policy decides what a full queue means — and, when the
    /// scheduler can price the request, a deadline budget below the best
    /// queue-depth-aware ETA is declined as [`SubmitError::Infeasible`].
    pub fn submit(&self, req: Request) -> Result<Ticket, SubmitError> {
        let key = req.key();
        let now = Instant::now();
        let snaps: Vec<DeviceSnapshot> = self
            .members
            .iter()
            .enumerate()
            .map(|(index, m)| DeviceSnapshot {
                index,
                device_id: &m.label,
                supports: m.router.read().unwrap().supports(&key),
                // inflight() = owned - answered, which already covers
                // requests still sitting in the admission queue (and
                // accounts for work stolen to/from this member).
                inflight: m.stats.inflight(),
                cost_ms: m.cost.read().unwrap().get(&key).copied(),
                slots: m.slots,
            })
            .collect();
        // Unserveable beats expired: a request nobody can route is
        // Unsupported no matter what its budget says.
        if !snaps.iter().any(|s| s.supports) {
            self.local.rejected.inc();
            return Err(SubmitError::Unsupported);
        }
        let deadline = match req.deadline {
            Some(budget) if budget.is_zero() => {
                // Fail fast instead of occupying a queue slot.
                self.local.shed.inc();
                return Err(SubmitError::DeadlineExceeded);
            }
            Some(budget) => {
                // Deadline-aware admission: decline a budget no member's
                // queue-depth-aware ETA can meet, instead of accepting
                // work the pipeline would shed later.
                if let Some(eta_ms) = self.scheduler.min_eta_ms(&key, &snaps) {
                    if eta_ms.is_finite() && eta_ms / 1e3 > budget.as_secs_f64() {
                        self.local.infeasible.inc();
                        return Err(SubmitError::Infeasible);
                    }
                }
                Some(now + budget)
            }
            None => None,
        };
        let Some(index) = self.scheduler.pick(&key, &snaps) else {
            self.local.rejected.inc();
            return Err(SubmitError::Unsupported);
        };
        let member = &self.members[index];
        debug_assert!(
            member.router.read().unwrap().supports(&key),
            "scheduler picked a member that cannot route the key"
        );
        let tx = member.admit_tx.as_ref().ok_or(SubmitError::ShuttingDown)?;
        let id = self.ids.next();
        let (ticket, reply) =
            Ticket::for_device(id, Default::default(), Some(member.label.clone()));
        let rr = ResizeRequest {
            id,
            key,
            image: req.image,
            priority: req.priority,
            deadline,
            // The ticket and the pipeline share the same token.
            cancel: ticket.cancel_token(),
            admitted: now,
            reply,
        };
        // Count the admission BEFORE the enqueue: the moment the request
        // is in the queue an idle peer may steal (and even answer) it,
        // and the victim's accounting must never observe a stolen
        // request that was not yet admitted. A failed enqueue rolls the
        // optimistic count back.
        member.stats.admitted.inc();
        match self.admission.admit(tx, rr) {
            Ok(()) => Ok(ticket),
            Err(e) => {
                member.stats.admitted.sub(1);
                // Only backpressure counts as a member rejection; a
                // budget that ran out while blocked is a shed — recorded
                // service-side, NOT on the member, because the request
                // was never admitted and member shed/admitted counters
                // must stay balanced for inflight(). A shutdown race is
                // neither.
                match e {
                    SubmitError::Saturated => member.stats.rejected.inc(),
                    SubmitError::DeadlineExceeded => self.local.shed.inc(),
                    _ => {}
                }
                Err(e)
            }
        }
    }

    /// The union of keys any member can serve, sorted.
    pub fn keys(&self) -> Vec<RequestKey> {
        let mut ks: Vec<RequestKey> = self
            .members
            .iter()
            .flat_map(|m| m.router.read().unwrap().keys())
            .collect();
        ks.sort();
        ks.dedup();
        ks
    }

    /// Hot-swap a device's tuned tile after a tuning refresh (e.g. a
    /// [`TuningDb`](crate::autotuner::TuningDb) cache update) changed
    /// the winner: rebuild the router of **every** member with this
    /// device id (a fleet may run several identical GPUs) under
    /// `TilePolicy::PerDevice(outcome)` and refresh the scheduler's ETA
    /// tables, **without draining the fleet** — batches already picked
    /// up keep the router they started with; the next batch routes
    /// through the new tile. Returns the new preferred tile.
    pub fn retune(&self, device_id: &str, outcome: &TuningOutcome) -> Result<Option<TileDim>> {
        let mut tile = None;
        let mut found = false;
        for member in self.members.iter().filter(|m| &*m.label == device_id) {
            found = true;
            let identity = member.device.as_ref().map(|d| d.id.as_str());
            let next = Arc::new(Router::for_device(
                &member.manifest,
                TilePolicy::PerDevice(outcome.clone()),
                identity,
            ));
            let cost = cost_table(&next, member.meter.as_deref());
            // Cost table first: a scheduler snapshot between the two
            // writes sees a (new-cost, old-router) pair, which only
            // mis-prices one pick — both maps cover the same key set.
            *member.cost.write().unwrap() = cost;
            tile = next.tile_pref;
            *member.router.write().unwrap() = next;
            member.stats.retunes.inc();
        }
        if !found {
            bail!("no fleet member '{device_id}'");
        }
        Ok(tile)
    }

    /// Number of fleet members.
    pub fn member_count(&self) -> usize {
        self.members.len()
    }

    /// Read-only views of every member, for per-device reporting.
    pub fn members(&self) -> Vec<MemberView<'_>> {
        self.members
            .iter()
            .map(|m| {
                let router = Arc::clone(&m.router.read().unwrap());
                MemberView {
                    label: &m.label,
                    device: m.device.as_ref(),
                    tile_pref: router.tile_pref,
                    batch_max: m.batch_max,
                    stats: &m.stats,
                    router,
                }
            })
            .collect()
    }

    /// The scheduler in use.
    pub fn scheduler_name(&self) -> &'static str {
        self.scheduler.name()
    }

    /// The admission policy in use.
    pub fn admission_name(&self) -> &'static str {
        self.admission.name()
    }

    /// Merged fleet-wide stats snapshot (counters + histograms summed
    /// over members; live stats keep updating after the call).
    pub fn stats(&self) -> ServingStats {
        let total = ServingStats::new();
        total.merge_from(&self.local);
        for m in &self.members {
            total.merge_from(&m.stats);
        }
        total
    }

    /// Reset every member's stats (e.g. after a warmup phase).
    pub fn reset_stats(&self) {
        self.local.reset();
        for m in &self.members {
            m.stats.reset();
        }
    }

    /// Graceful shutdown: stop admissions, drain every member's
    /// pipeline, join all threads. Returns the final merged stats.
    pub fn shutdown(mut self) -> ServingStats {
        self.shutdown_inner();
        self.stats()
    }

    fn shutdown_inner(&mut self) {
        for m in &mut self.members {
            m.admit_tx.take(); // closes admissions → batcher exits → workers exit
        }
        for m in &mut self.members {
            if let Some(b) = m.batcher.take() {
                let _ = b.join();
            }
            for w in m.workers.drain(..) {
                let _ = w.join();
            }
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        if self.members.iter().any(|m| m.admit_tx.is_some()) {
            self.shutdown_inner();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::admission::{BlockWithTimeout, RejectWhenFull};
    use crate::coordinator::request::Priority;
    use crate::coordinator::scheduler::RoundRobin;
    use crate::image::{generate, Interpolator};
    use crate::runtime::MockEngine;
    use std::path::PathBuf;

    fn manifest() -> Manifest {
        Manifest::parse(
            r#"{
              "version": 1,
              "artifacts": [
                {"name": "bl_s2_b4", "kernel": "bilinear", "src": [16, 16],
                 "scale": 2, "batch": 4, "tile": [4, 32], "path": "x"},
                {"name": "nn_s4_b2", "kernel": "nearest", "src": [16, 16],
                 "scale": 4, "batch": 2, "tile": [4, 32], "path": "x"}
              ]
            }"#,
            PathBuf::from("."),
        )
        .unwrap()
    }

    fn cfg() -> ServingConfig {
        ServingConfig {
            workers: 2,
            batch_max: Some(4),
            batch_deadline_ms: 2.0,
            queue_cap: 64,
            ..ServingConfig::default()
        }
    }

    fn start(backend: Arc<dyn ResizeBackend>) -> Service {
        let m = manifest();
        ServiceBuilder::new(&cfg(), &m)
            .backend(backend, TilePolicy::PortableFallback)
            .admission(BlockWithTimeout(Duration::from_secs(10)))
            .build()
            .unwrap()
    }

    fn req(kernel: Interpolator, img: crate::image::Image<f32>, scale: u32) -> Request {
        Request::new(kernel, img, scale)
    }

    #[test]
    fn end_to_end_requests_complete_correctly() {
        let svc = start(Arc::new(MockEngine::new()));
        let img = generate::test_scene(16, 16, 9);
        let want = crate::image::bilinear(&img, 2);
        let tickets: Vec<_> = (0..20)
            .map(|_| svc.submit(req(Interpolator::Bilinear, img.clone(), 2)).unwrap())
            .collect();
        for t in tickets {
            let out = t.wait().unwrap();
            assert_eq!(out.width(), 32);
            assert!(out.max_abs_diff(&want) < 1e-6);
        }
        let stats = svc.shutdown();
        assert_eq!(stats.completed.get(), 20);
        assert_eq!(stats.failed.get(), 0);
        assert!(stats.batches.get() <= 20);
        assert!(stats.mean_batch() >= 1.0);
        assert_eq!(
            stats.latency_by_class[Priority::Interactive.index()].count(),
            20
        );
    }

    #[test]
    fn unsupported_shape_rejected_fast() {
        let svc = start(Arc::new(MockEngine::new()));
        let img = generate::gradient(9, 9);
        match svc.submit(req(Interpolator::Bilinear, img, 2)) {
            Err(SubmitError::Unsupported) => {}
            other => panic!("expected Unsupported, got {other:?}"),
        }
        let img16 = generate::gradient(16, 16);
        assert!(matches!(
            svc.submit(req(Interpolator::Bicubic, img16, 2)),
            Err(SubmitError::Unsupported)
        ));
        let stats = svc.shutdown();
        assert_eq!(stats.rejected.get(), 2);
    }

    #[test]
    fn mixed_kernels_route_independently() {
        let svc = start(Arc::new(MockEngine::new()));
        let img = generate::test_scene(16, 16, 2);
        let t1 = svc.submit(req(Interpolator::Bilinear, img.clone(), 2)).unwrap();
        let t2 = svc.submit(req(Interpolator::Nearest, img.clone(), 4)).unwrap();
        assert_eq!(t1.wait().unwrap().width(), 32);
        assert_eq!(t2.wait().unwrap().width(), 64);
    }

    #[test]
    fn deadline_flushes_partial_batches() {
        // One request with batch_max 4: only the deadline can flush it.
        let svc = start(Arc::new(MockEngine::new()));
        let img = generate::test_scene(16, 16, 4);
        let t = svc.submit(req(Interpolator::Bilinear, img, 2)).expect("admitted");
        let out = t.wait().unwrap();
        assert_eq!(out.height(), 32);
    }

    #[test]
    fn zero_deadline_fails_fast() {
        let svc = start(Arc::new(MockEngine::new()));
        let img = generate::test_scene(16, 16, 4);
        let r = req(Interpolator::Bilinear, img, 2).deadline(Duration::ZERO);
        assert!(matches!(
            svc.submit(r),
            Err(SubmitError::DeadlineExceeded)
        ));
        let stats = svc.shutdown();
        assert_eq!(stats.shed.get(), 1);
        assert_eq!(stats.completed.get(), 0);
    }

    #[test]
    fn backend_failures_reported_per_request() {
        let svc = start(Arc::new(MockEngine::failing_every(1)));
        let img = generate::test_scene(16, 16, 5);
        let t = svc.submit(req(Interpolator::Bilinear, img, 2)).unwrap();
        assert!(t.wait().is_err());
        let stats = svc.shutdown();
        assert_eq!(stats.failed.get(), 1);
    }

    #[test]
    fn backpressure_saturates() {
        // Slow backend + tiny queue + non-blocking admission: Saturated.
        let slow = MockEngine::with_delay(Duration::from_millis(30));
        let m = manifest();
        let small = ServingConfig {
            workers: 1,
            batch_max: Some(1),
            batch_deadline_ms: 0.1,
            queue_cap: 2,
            ..ServingConfig::default()
        };
        let svc = ServiceBuilder::new(&small, &m)
            .backend(Arc::new(slow), TilePolicy::PortableFallback)
            .admission(RejectWhenFull)
            .build()
            .unwrap();
        let img = generate::test_scene(16, 16, 6);
        let mut saturated = false;
        let mut tickets = Vec::new();
        for _ in 0..64 {
            match svc.submit(req(Interpolator::Bilinear, img.clone(), 2)) {
                Ok(t) => tickets.push(t),
                Err(SubmitError::Saturated) => {
                    saturated = true;
                    break;
                }
                Err(e) => panic!("unexpected {e:?}"),
            }
        }
        assert!(saturated, "queue should saturate under a slow backend");
        for t in tickets {
            let _ = t.wait();
        }
        let stats = svc.shutdown();
        assert!(stats.rejected.get() >= 1);
    }

    #[test]
    fn shutdown_drains_pending() {
        let svc = start(Arc::new(MockEngine::new()));
        let img = generate::test_scene(16, 16, 7);
        let tickets: Vec<_> = (0..10)
            .map(|_| svc.submit(req(Interpolator::Bilinear, img.clone(), 2)).unwrap())
            .collect();
        let stats = svc.shutdown(); // must drain, not drop
        assert_eq!(stats.completed.get() + stats.failed.get(), 10);
        for t in tickets {
            let _ = t.wait(); // all replies delivered
        }
    }

    #[test]
    fn two_member_fleet_round_robin_spreads_load() {
        let m = manifest();
        let svc = ServiceBuilder::new(&cfg(), &m)
            .device(
                crate::device::find_device("gtx260").unwrap(),
                Arc::new(MockEngine::new()),
                TilePolicy::PortableFallback,
            )
            .device(
                crate::device::find_device("fermi").unwrap(),
                Arc::new(MockEngine::new()),
                TilePolicy::PortableFallback,
            )
            .scheduler(RoundRobin::default())
            .admission(BlockWithTimeout(Duration::from_secs(10)))
            .build()
            .unwrap();
        assert_eq!(svc.member_count(), 2);
        let img = generate::test_scene(16, 16, 8);
        let tickets: Vec<_> = (0..12)
            .map(|_| svc.submit(req(Interpolator::Bilinear, img.clone(), 2)).unwrap())
            .collect();
        let mut per_dev: HashMap<String, usize> = HashMap::new();
        for t in &tickets {
            *per_dev
                .entry(t.device_id().unwrap().to_string())
                .or_default() += 1;
        }
        assert_eq!(per_dev.get("gtx260"), Some(&6));
        assert_eq!(per_dev.get("fermi"), Some(&6));
        for t in tickets {
            t.wait().unwrap();
        }
        let views_completed: u64 = svc.members().iter().map(|v| v.stats.completed.get()).sum();
        assert_eq!(views_completed, 12);
        let stats = svc.shutdown();
        assert_eq!(stats.completed.get(), 12);
        assert!(stats.sim_cost_ns.get() > 0, "named members meter sim cost");
    }

    #[test]
    fn per_member_batch_max_derives_from_capability() {
        let m = manifest();
        let auto = ServingConfig {
            workers: 1,
            batch_max: None,
            ..ServingConfig::default()
        };
        let svc = ServiceBuilder::new(&auto, &m)
            .device(
                crate::device::find_device("8800gts").unwrap(), // cc1.0
                Arc::new(MockEngine::new()),
                TilePolicy::PortableFallback,
            )
            .device(
                crate::device::find_device("fermi").unwrap(), // cc2.0
                Arc::new(MockEngine::new()),
                TilePolicy::PortableFallback,
            )
            .backend(Arc::new(MockEngine::new()), TilePolicy::PortableFallback)
            .build()
            .unwrap();
        let caps: Vec<usize> = svc.members().iter().map(|v| v.batch_max).collect();
        assert_eq!(caps, vec![4, 16, crate::coordinator::ANON_BATCH_MAX]);
        svc.shutdown();
        // The override pins everyone.
        let pinned = ServingConfig {
            workers: 1,
            batch_max: Some(2),
            ..ServingConfig::default()
        };
        let svc = ServiceBuilder::new(&pinned, &m)
            .device(
                crate::device::find_device("fermi").unwrap(),
                Arc::new(MockEngine::new()),
                TilePolicy::PortableFallback,
            )
            .build()
            .unwrap();
        assert_eq!(svc.members()[0].batch_max, 2);
        svc.shutdown();
    }

    #[test]
    fn infeasible_deadline_declined_by_cost_eta_only() {
        use crate::coordinator::scheduler::CostModelEta;
        let m = manifest();
        let build = |cost_eta: bool| {
            let b = ServiceBuilder::new(&cfg(), &m).device(
                crate::device::find_device("gtx260").unwrap(),
                Arc::new(MockEngine::new()),
                TilePolicy::PortableFallback,
            );
            let b = if cost_eta {
                b.scheduler(CostModelEta)
            } else {
                b.scheduler(RoundRobin::default())
            };
            b.admission(BlockWithTimeout(Duration::from_secs(10)))
                .build()
                .unwrap()
        };
        // cost-eta knows the per-request sim cost: a 1ns budget is
        // provably unmeetable and is declined up front.
        let svc = build(true);
        let img = generate::test_scene(16, 16, 11);
        let r = req(Interpolator::Bilinear, img.clone(), 2).deadline(Duration::from_nanos(1));
        assert!(matches!(svc.submit(r), Err(SubmitError::Infeasible)));
        // ...while an unpriced request and a generous budget still flow.
        let ok = svc
            .submit(req(Interpolator::Bilinear, img.clone(), 2).deadline(Duration::from_secs(5)))
            .unwrap();
        ok.wait().unwrap();
        let stats = svc.shutdown();
        assert_eq!(stats.infeasible.get(), 1);
        assert_eq!(stats.shed.get(), 0, "declined, not shed");
        // round-robin has no cost information: the same doomed budget is
        // admitted and shed later by the pipeline instead.
        let svc = build(false);
        let r = req(Interpolator::Bilinear, img, 2).deadline(Duration::from_nanos(1));
        match svc.submit(r) {
            Ok(t) => {
                let _ = t.wait();
            }
            Err(SubmitError::DeadlineExceeded) => {}
            Err(e) => panic!("unexpected {e:?}"),
        }
        let stats = svc.shutdown();
        assert_eq!(stats.infeasible.get(), 0);
    }

    #[test]
    fn retune_hot_swaps_tile_without_draining() {
        use crate::autotuner::{portable_over, DeviceTuning, TunedPoint};
        let fast = |tile: TileDim, other: TileDim| {
            let dt = DeviceTuning::from_points(
                "gtx260".to_string(),
                vec![
                    TunedPoint { tile, ms: 1.0 },
                    TunedPoint {
                        tile: other,
                        ms: 2.0,
                    },
                ],
                2,
            )
            .unwrap();
            let per_device = vec![dt];
            TuningOutcome {
                kernel: Interpolator::Bilinear,
                scale: 2,
                src: (16, 16),
                strategy: "test".to_string(),
                evaluations: 2,
                portable: portable_over(&per_device),
                per_device,
            }
        };
        let m = Manifest::parse(
            r#"{
              "version": 1,
              "artifacts": [
                {"name": "a", "kernel": "bilinear", "src": [16, 16],
                 "scale": 2, "batch": 4, "tile": [4, 32], "path": "x"},
                {"name": "b", "kernel": "bilinear", "src": [16, 16],
                 "scale": 2, "batch": 4, "tile": [8, 8], "path": "x"}
              ]
            }"#,
            PathBuf::from("."),
        )
        .unwrap();
        let t32x4 = TileDim::new(32, 4);
        let t8x8 = TileDim::new(8, 8);
        let svc = ServiceBuilder::new(&cfg(), &m)
            .device(
                crate::device::find_device("gtx260").unwrap(),
                Arc::new(MockEngine::new()),
                TilePolicy::PerDevice(fast(t32x4, t8x8)),
            )
            .admission(BlockWithTimeout(Duration::from_secs(10)))
            .build()
            .unwrap();
        assert_eq!(svc.members()[0].tile_pref, Some(t32x4));
        let img = generate::test_scene(16, 16, 12);
        // Keep traffic flowing across the swap: no drain, no rebuild.
        let before = svc
            .submit(req(Interpolator::Bilinear, img.clone(), 2))
            .unwrap();
        let tile = svc.retune("gtx260", &fast(t8x8, t32x4)).unwrap();
        assert_eq!(tile, Some(t8x8));
        assert_eq!(svc.members()[0].tile_pref, Some(t8x8));
        let after = svc
            .submit(req(Interpolator::Bilinear, img, 2))
            .unwrap();
        before.wait().unwrap();
        after.wait().unwrap();
        assert!(svc.retune("ghost", &fast(t8x8, t32x4)).is_err());
        let stats = svc.shutdown();
        assert_eq!(stats.retunes.get(), 1);
        assert_eq!(stats.completed.get(), 2);
    }

    #[test]
    fn builder_rejects_bad_config_and_empty_fleet() {
        let m = manifest();
        let bad = ServingConfig {
            workers: 0,
            ..ServingConfig::default()
        };
        let err = ServiceBuilder::new(&bad, &m)
            .backend(Arc::new(MockEngine::new()), TilePolicy::PortableFallback)
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("invalid serving configuration"), "{err}");
        assert!(ServiceBuilder::new(&cfg(), &m).build().is_err(), "no members");
    }
}
