//! Work-stealing policy: when one fleet member's admission queue runs
//! hot while another sits idle, the idle member (the *thief*) pulls
//! compatible pending requests out of the hot member's (the *victim's*)
//! queue and serves them through its **own** tuned-tile router — the
//! adaptive complement to per-device tuning under skewed traffic.
//!
//! The *selection* is a pure function ([`select_steals`]) over a
//! snapshot of the victim's queue, so its invariants are
//! property-testable without threads (see `rust/tests/properties.rs`);
//! the batcher thread applies it through
//! [`Receiver::steal_by`](crate::exec::Receiver::steal_by), which
//! removes the selected items atomically under the queue lock.
//!
//! Invariants the selection guarantees:
//!
//! 1. only requests the thief's router can serve are taken;
//! 2. cancelled and deadline-expired requests are never taken (they
//!    stay put for the victim's sweep to shed with the right error);
//! 3. priority ordering is respected: `Batch`-class work is stolen
//!    before `Interactive`-class work — an interactive request moves
//!    only when every stealable batch request moves with it;
//! 4. newest-first, at most half the victim's backlog per attempt — the
//!    victim keeps the oldest requests it is already about to batch.

use super::request::{Priority, RequestKey, ResizeRequest};
use std::collections::VecDeque;
use std::time::Instant;

/// When and how much to steal.
#[derive(Debug, Clone, Copy)]
pub struct StealPolicy {
    /// Minimum victim backlog (queued requests) before stealing is
    /// worthwhile; below this the victim drains faster on its own.
    pub min_victim_backlog: usize,
    /// Cap on requests taken per steal attempt.
    pub max_per_attempt: usize,
}

impl Default for StealPolicy {
    fn default() -> Self {
        StealPolicy {
            min_victim_backlog: 4,
            max_per_attempt: 8,
        }
    }
}

/// Minimum number of live requests a pending group must hold before a
/// whole-batch migration is worth the churn — a 1-request group moves
/// nothing a plain queue steal wouldn't.
pub const MIGRATE_MIN_LIVE: usize = 2;

/// One pending group at a victim's batcher, as seen by a would-be
/// migrating thief: the group key plus how many of its requests are
/// still live (neither cancelled nor deadline-expired) at selection
/// time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MigrationGroup {
    pub key: RequestKey,
    pub live: usize,
}

/// Pick which of a victim's *pending batches* an idle thief should
/// claim wholesale — the whole-group analogue of [`select_steals`],
/// used when a freshly added member must become useful within one
/// batch window instead of nibbling single queued requests.
///
/// Pure over a snapshot of the victim's pending table so its
/// invariants are property-testable (see `rust/tests/properties.rs`):
///
/// 1. nothing is ever taken from a draining victim — its batcher is
///    the one place that work is guaranteed to finish;
/// 2. only groups the thief's router can serve are candidates;
/// 3. cancelled and expired requests never count toward a group's
///    worth (`live` excludes them by construction — the extraction
///    path sheds them victim-side with the right error);
/// 4. only groups with at least `min_live` live requests qualify, and
///    the fullest such group wins (lowest index on ties), so migration
///    fires once per batch window, not per request.
///
/// Returns the index of the winning group in `groups`, or `None`.
pub fn select_batch_migration(
    groups: &[MigrationGroup],
    supports: impl Fn(&RequestKey) -> bool,
    victim_draining: bool,
    min_live: usize,
) -> Option<usize> {
    if victim_draining {
        return None;
    }
    let floor = min_live.max(1);
    let mut best: Option<usize> = None;
    for (i, g) in groups.iter().enumerate() {
        if g.live < floor || !supports(&g.key) {
            continue;
        }
        match best {
            Some(b) if groups[b].live >= g.live => {}
            _ => best = Some(i),
        }
    }
    best
}

/// Pick which of the victim's queued requests an idle thief should
/// steal. Returns indices into `queue` (0 = oldest); see the module
/// docs for the invariants. `supports` is the thief's own routing
/// predicate — a stolen request is re-routed through the thief's
/// tuned tile, so the thief must be able to serve its key.
pub fn select_steals(
    queue: &VecDeque<ResizeRequest>,
    supports: impl Fn(&RequestKey) -> bool,
    now: Instant,
    max: usize,
) -> Vec<usize> {
    let budget = max.min(queue.len() / 2);
    if budget == 0 {
        return Vec::new();
    }
    let stealable =
        |r: &ResizeRequest| !r.is_cancelled() && !r.is_expired(now) && supports(&r.key);
    let mut picked = Vec::with_capacity(budget);
    // Two passes — batch-class work first — walking from the back
    // (newest) of the queue.
    for class in [Priority::Batch, Priority::Interactive] {
        for i in (0..queue.len()).rev() {
            if picked.len() >= budget {
                return picked;
            }
            if queue[i].priority == class && stealable(&queue[i]) {
                picked.push(i);
            }
        }
    }
    picked
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Ticket;
    use crate::image::{generate, Interpolator};
    use std::time::Duration;

    fn req(scale: u32, priority: Priority) -> ResizeRequest {
        let img = generate::gradient(16, 16);
        let (_t, tx) = Ticket::new(0);
        let mut r = ResizeRequest::bare(
            0,
            RequestKey::of(Interpolator::Bilinear, &img, scale),
            img,
            tx,
        );
        r.priority = priority;
        r
    }

    #[test]
    fn steals_at_most_half_newest_first() {
        let q: VecDeque<ResizeRequest> =
            (0..6).map(|_| req(2, Priority::Interactive)).collect();
        let picked = select_steals(&q, |_| true, Instant::now(), 100);
        assert_eq!(picked, vec![5, 4, 3], "newest half, back first");
        let capped = select_steals(&q, |_| true, Instant::now(), 2);
        assert_eq!(capped.len(), 2);
    }

    #[test]
    fn empty_and_singleton_queues_yield_nothing() {
        let empty = VecDeque::new();
        assert!(select_steals(&empty, |_| true, Instant::now(), 8).is_empty());
        let one: VecDeque<ResizeRequest> = [req(2, Priority::Batch)].into_iter().collect();
        assert!(select_steals(&one, |_| true, Instant::now(), 8).is_empty());
    }

    #[test]
    fn batch_class_is_stolen_before_interactive() {
        // Oldest->newest: I B I B. Budget 2 must take both batch
        // requests (indices 3 and 1), not the newer interactive at 2.
        let q: VecDeque<ResizeRequest> = [
            req(2, Priority::Interactive),
            req(2, Priority::Batch),
            req(2, Priority::Interactive),
            req(2, Priority::Batch),
        ]
        .into_iter()
        .collect();
        let picked = select_steals(&q, |_| true, Instant::now(), 2);
        assert_eq!(picked, vec![3, 1]);
    }

    #[test]
    fn migration_picks_the_fullest_routable_group() {
        let img = generate::gradient(16, 16);
        let key = |scale| RequestKey::of(Interpolator::Bilinear, &img, scale);
        let groups = [
            MigrationGroup { key: key(2), live: 3 },
            MigrationGroup { key: key(4), live: 6 }, // unroutable below
            MigrationGroup { key: key(2), live: 5 },
        ];
        let pick = select_batch_migration(&groups, |k| k.scale == 2, false, MIGRATE_MIN_LIVE);
        assert_eq!(pick, Some(2), "fullest routable group wins");
        // First index wins ties.
        let tied = [
            MigrationGroup { key: key(2), live: 5 },
            MigrationGroup { key: key(2), live: 5 },
        ];
        assert_eq!(
            select_batch_migration(&tied, |_| true, false, MIGRATE_MIN_LIVE),
            Some(0)
        );
    }

    #[test]
    fn migration_respects_drain_floor_and_routability() {
        let img = generate::gradient(16, 16);
        let g = [MigrationGroup {
            key: RequestKey::of(Interpolator::Bilinear, &img, 2),
            live: 4,
        }];
        assert_eq!(
            select_batch_migration(&g, |_| true, true, MIGRATE_MIN_LIVE),
            None,
            "a draining victim is never migrated from"
        );
        assert_eq!(
            select_batch_migration(&g, |_| false, false, MIGRATE_MIN_LIVE),
            None,
            "an unroutable group is never taken"
        );
        assert_eq!(
            select_batch_migration(&g, |_| true, false, 5),
            None,
            "groups below the live floor are left to the victim"
        );
        // A zero floor still requires at least one live request.
        let empty = [MigrationGroup {
            key: RequestKey::of(Interpolator::Bilinear, &img, 2),
            live: 0,
        }];
        assert_eq!(select_batch_migration(&empty, |_| true, false, 0), None);
    }

    #[test]
    fn skips_unsupported_cancelled_and_expired() {
        let mut q: VecDeque<ResizeRequest> = VecDeque::new();
        q.push_back(req(2, Priority::Batch)); // healthy
        q.push_back(req(4, Priority::Batch)); // thief cannot route scale 4
        let cancelled = req(2, Priority::Batch);
        cancelled.cancel.cancel();
        q.push_back(cancelled);
        let mut expired = req(2, Priority::Batch);
        expired.deadline = Some(Instant::now() - Duration::from_millis(1));
        q.push_back(expired);
        let picked = select_steals(&q, |k| k.scale == 2, Instant::now(), 8);
        assert_eq!(picked, vec![0], "only the healthy routable request");
    }
}
