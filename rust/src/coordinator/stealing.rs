//! Work-stealing policy: when one fleet member's admission queue runs
//! hot while another sits idle, the idle member (the *thief*) pulls
//! compatible pending requests out of the hot member's (the *victim's*)
//! queue and serves them through its **own** tuned-tile router — the
//! adaptive complement to per-device tuning under skewed traffic.
//!
//! The *selection* is a pure function ([`select_steals`]) over a
//! snapshot of the victim's queue, so its invariants are
//! property-testable without threads (see `rust/tests/properties.rs`);
//! the batcher thread applies it through
//! [`Receiver::steal_by`](crate::exec::Receiver::steal_by), which
//! removes the selected items atomically under the queue lock.
//!
//! Invariants the selection guarantees:
//!
//! 1. only requests the thief's router can serve are taken;
//! 2. cancelled and deadline-expired requests are never taken (they
//!    stay put for the victim's sweep to shed with the right error);
//! 3. priority ordering is respected: `Batch`-class work is stolen
//!    before `Interactive`-class work — an interactive request moves
//!    only when every stealable batch request moves with it;
//! 4. newest-first, at most half the victim's backlog per attempt — the
//!    victim keeps the oldest requests it is already about to batch.

use super::request::{Priority, RequestKey, ResizeRequest};
use std::collections::VecDeque;
use std::time::Instant;

/// When and how much to steal.
#[derive(Debug, Clone, Copy)]
pub struct StealPolicy {
    /// Minimum victim backlog (queued requests) before stealing is
    /// worthwhile; below this the victim drains faster on its own.
    pub min_victim_backlog: usize,
    /// Cap on requests taken per steal attempt.
    pub max_per_attempt: usize,
}

impl Default for StealPolicy {
    fn default() -> Self {
        StealPolicy {
            min_victim_backlog: 4,
            max_per_attempt: 8,
        }
    }
}

/// Pick which of the victim's queued requests an idle thief should
/// steal. Returns indices into `queue` (0 = oldest); see the module
/// docs for the invariants. `supports` is the thief's own routing
/// predicate — a stolen request is re-routed through the thief's
/// tuned tile, so the thief must be able to serve its key.
pub fn select_steals(
    queue: &VecDeque<ResizeRequest>,
    supports: impl Fn(&RequestKey) -> bool,
    now: Instant,
    max: usize,
) -> Vec<usize> {
    let budget = max.min(queue.len() / 2);
    if budget == 0 {
        return Vec::new();
    }
    let stealable =
        |r: &ResizeRequest| !r.is_cancelled() && !r.is_expired(now) && supports(&r.key);
    let mut picked = Vec::with_capacity(budget);
    // Two passes — batch-class work first — walking from the back
    // (newest) of the queue.
    for class in [Priority::Batch, Priority::Interactive] {
        for i in (0..queue.len()).rev() {
            if picked.len() >= budget {
                return picked;
            }
            if queue[i].priority == class && stealable(&queue[i]) {
                picked.push(i);
            }
        }
    }
    picked
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Ticket;
    use crate::image::{generate, Interpolator};
    use std::time::Duration;

    fn req(scale: u32, priority: Priority) -> ResizeRequest {
        let img = generate::gradient(16, 16);
        let (_t, tx) = Ticket::new(0);
        let mut r = ResizeRequest::bare(
            0,
            RequestKey::of(Interpolator::Bilinear, &img, scale),
            img,
            tx,
        );
        r.priority = priority;
        r
    }

    #[test]
    fn steals_at_most_half_newest_first() {
        let q: VecDeque<ResizeRequest> =
            (0..6).map(|_| req(2, Priority::Interactive)).collect();
        let picked = select_steals(&q, |_| true, Instant::now(), 100);
        assert_eq!(picked, vec![5, 4, 3], "newest half, back first");
        let capped = select_steals(&q, |_| true, Instant::now(), 2);
        assert_eq!(capped.len(), 2);
    }

    #[test]
    fn empty_and_singleton_queues_yield_nothing() {
        let empty = VecDeque::new();
        assert!(select_steals(&empty, |_| true, Instant::now(), 8).is_empty());
        let one: VecDeque<ResizeRequest> = [req(2, Priority::Batch)].into_iter().collect();
        assert!(select_steals(&one, |_| true, Instant::now(), 8).is_empty());
    }

    #[test]
    fn batch_class_is_stolen_before_interactive() {
        // Oldest->newest: I B I B. Budget 2 must take both batch
        // requests (indices 3 and 1), not the newer interactive at 2.
        let q: VecDeque<ResizeRequest> = [
            req(2, Priority::Interactive),
            req(2, Priority::Batch),
            req(2, Priority::Interactive),
            req(2, Priority::Batch),
        ]
        .into_iter()
        .collect();
        let picked = select_steals(&q, |_| true, Instant::now(), 2);
        assert_eq!(picked, vec![3, 1]);
    }

    #[test]
    fn skips_unsupported_cancelled_and_expired() {
        let mut q: VecDeque<ResizeRequest> = VecDeque::new();
        q.push_back(req(2, Priority::Batch)); // healthy
        q.push_back(req(4, Priority::Batch)); // thief cannot route scale 4
        let cancelled = req(2, Priority::Batch);
        cancelled.cancel.cancel();
        q.push_back(cancelled);
        let mut expired = req(2, Priority::Batch);
        expired.deadline = Some(Instant::now() - Duration::from_millis(1));
        q.push_back(expired);
        let picked = select_steals(&q, |k| k.scale == 2, Instant::now(), 8);
        assert_eq!(picked, vec![0], "only the healthy routable request");
    }
}
