//! The serving layer (L3): a **fleet-aware** image-resize service in the
//! style of an inference router, split into two typed planes:
//!
//! * the **data plane** — a [`Fleet`] of N device members, each with its
//!   own tuned-tile router, bounded admission queue, dynamic batcher
//!   (size + deadline), and worker pool; every typed [`Request`] is
//!   scheduled onto one of them via [`Fleet::submit`];
//! * the **control plane** — a [`FleetController`] carrying lifecycle
//!   and reconfiguration commands against the *live* fleet:
//!   [`add_member`](FleetController::add_member) /
//!   [`remove_member`](FleetController::remove_member) (with
//!   [`DrainMode`] semantics) / [`drain`](FleetController::drain) /
//!   [`retune`](FleetController::retune) /
//!   [`set_scheduler`](FleetController::set_scheduler) /
//!   [`set_admission`](FleetController::set_admission) /
//!   [`set_steal_config`](FleetController::set_steal_config), plus an
//!   epoch-stamped [`topology`](FleetController::topology) snapshot.
//!
//! Membership lives in a versioned registry (epoch-stamped `Arc`
//! snapshots); schedulers, batchers, and thieves read it per decision,
//! so elastic membership is race-free by construction. The
//! [`daemon::RetuneDaemon`] closes the loop from a refreshed
//! [`TuningDb`](crate::autotuner::TuningDb) file back into
//! `FleetController::retune` (`tilekit serve --watch-db`).
//!
//! Data flow:
//!
//! ```text
//! submit(Request{kernel,image,scale,priority,deadline})
//!    │
//!    ▼
//! Scheduler (round-robin | least-loaded | cost-eta) picks a device member
//!    │
//!    ▼
//! AdmissionPolicy (reject | block | shed-batch) ──► member admission queue
//!                                                        │
//!            ┌───────────────────────────────────────────┤  (one per device)
//!            ▼                                           ▼
//!     member "gtx260"                             member "fermi"
//!     batcher ── sheds cancelled/expired,         batcher ── …
//!       │        groups by (kernel,src,scale),      │
//!       │        flushes at batch_max or deadline   │
//!       ▼                                           ▼
//!     batch channel ──► worker pool ──► backend   batch channel ──► …
//!       routed via the DEVICE'S OWN tuned tile (TilePolicy::PerDevice)
//!            │
//! Ticket::wait()/try_wait()/cancel() ◄── per-request reply channel
//! ```
//!
//! The paper's tiling result enters through each member's router:
//! artifact variants are keyed by Pallas tile, and [`router::Router`]
//! resolves which variant a device prefers through a
//! [`router::TilePolicy`]:
//!
//! * `TilePolicy::Fixed(tile)` — pin one tile (benchmark overrides);
//! * `TilePolicy::PerDevice(outcome)` — route each fleet member to its
//!   own tuned tile from a [`crate::autotuner::TuningOutcome`], falling
//!   back to the outcome's portable (min-max regret) pick for devices
//!   the tuner has not seen — re-tune, rebuild the service, done. This
//!   is how "an optimized tiling strategy on one GPU model is not always
//!   a good solution ... on other GPU models" becomes an operational
//!   knob: a heterogeneous fleet with per-device tiles beats any single
//!   fixed tile on aggregate sim cost (see `examples/fleet_serving.rs`);
//! * `TilePolicy::PortableFallback` — no tuned preference; the
//!   backend-optimal variant order (largest Pallas tile first on the
//!   CPU PJRT backend).
//!
//! QoS: requests carry a [`Priority`] class (`Interactive` / `Batch`)
//! and an optional deadline. Expired requests are shed *before* they
//! reach a worker (`SubmitError::DeadlineExceeded` at submit when the
//! budget is already zero); a deadline-aware scheduler (`cost-eta`)
//! additionally declines budgets no member's queue-depth-aware ETA can
//! meet (`SubmitError::Infeasible`); [`Ticket::cancel`] sheds a queued
//! request before batch pickup. Per-class latency histograms live in
//! [`ServingStats`].
//!
//! The runtime is **adaptive** under skewed traffic:
//!
//! * work-stealing — an idle member's batcher pulls compatible pending
//!   requests from a hot peer's admission queue and serves them through
//!   its *own* tuned tile ([`stealing`], `ServingStats::{steals,stolen}`);
//! * batch migration — when every queue is shallow but a peer's batcher
//!   holds a deep pending group, an idle member claims the WHOLE group
//!   ([`select_batch_migration`], `ServingStats::migrated_batches`), so
//!   a freshly added member becomes useful within one batch window;
//! * autoscaling — [`autoscaler::Autoscaler`] closes the capacity loop:
//!   a pure watermark policy over [`ServingStats`] drives
//!   `add_member`/`drain`/`remove_member` against a standby-device pool
//!   (`tilekit serve --autoscale`);
//! * per-member `batch_max` — each member's dynamic-batch cap derives
//!   from its compute capability (a Fermi-class part batches bigger
//!   than a cc1.0 one) unless `ServingConfig::batch_max` overrides it;
//! * tuned-tile invalidation — [`FleetController::retune`] hot-swaps a
//!   member's router when a tuning refresh changes the winner, without
//!   draining.

pub mod admission;
pub mod autoscaler;
pub mod batcher;
pub mod daemon;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod server;
pub mod stats;
pub mod stealing;
pub mod worker;

pub use admission::{
    admission_by_name, AdmissionPolicy, BlockWithTimeout, RejectWhenFull, ShedBatchFirst,
};
pub use autoscaler::{
    Autoscaler, AutoscalerHandle, AutoscalerOpts, AutoscalerStats, AutoscalerUpdate,
    AutoscalerView, StandbyMember,
};
pub use daemon::{RetuneDaemon, RetuneDaemonStats, RetuneSpec};
pub use request::{CancelToken, Priority, Request, RequestKey, ResizeRequest, Ticket};
pub use router::{Router, SharedRouter, TilePolicy};
pub use scheduler::{
    scheduler_by_name, steal_discount, Biased, CostMeter, CostModelEta, DeviceSnapshot,
    LeastLoaded, RoundRobin, Scheduler,
};
pub use server::{
    DrainMode, Fleet, FleetBuilder, FleetController, MemberView, PlanMetrics, SubmitError,
    TopologyView, ANON_BATCH_MAX,
};
// Deprecated pre-control-plane names, re-exported so downstream code
// keeps compiling (with a deprecation warning) until it migrates.
#[allow(deprecated)]
pub use server::{Service, ServiceBuilder};
pub use stats::ServingStats;
pub use stealing::{
    select_batch_migration, select_steals, MigrationGroup, StealPolicy, MIGRATE_MIN_LIVE,
};
