//! The serving coordinator (L3): an image-resize service in the style of
//! an inference router — bounded admission queue with backpressure, a
//! dynamic batcher (size + deadline), a worker pool executing AOT PJRT
//! artifacts, per-request latency accounting, and graceful shutdown.
//!
//! Data flow:
//!
//! ```text
//! submit() ──► admission queue (bounded) ──► batcher thread
//!                                              │ groups by (kernel, src, scale),
//!                                              │ flushes at batch_max or deadline
//!                                              ▼
//!                                        batch channel ──► worker pool ──► PJRT
//!                                                              │
//! Ticket::wait() ◄── per-request reply channel ◄───────────────┘
//! ```
//!
//! The paper's tiling result enters through the router: artifact variants
//! are keyed by Pallas tile, and [`router::Router`] resolves which
//! variant to prefer through a [`router::TilePolicy`]:
//!
//! * `TilePolicy::Fixed(tile)` — pin one tile (benchmark overrides);
//! * `TilePolicy::PerDevice(outcome)` — route each serving device to its
//!   own tuned tile from a [`crate::autotuner::TuningOutcome`], falling
//!   back to the outcome's portable (min-max regret) pick for devices
//!   the tuner has not seen — re-tune, rebuild the router, done;
//! * `TilePolicy::PortableFallback` — no tuned preference; the
//!   backend-optimal variant order (largest Pallas tile first on the
//!   CPU PJRT backend).

pub mod batcher;
pub mod request;
pub mod router;
pub mod server;
pub mod stats;
pub mod worker;

pub use request::{RequestKey, ResizeRequest, Ticket};
pub use router::{Router, TilePolicy};
pub use server::{Coordinator, SubmitError};
pub use stats::ServingStats;
