//! Serving statistics: request/batch counters, latency histograms (both
//! aggregate and per [`Priority`] class), and per-device simulated-cost
//! accounting, shared (via `Arc`) between the pipeline stages and the
//! caller. A [`Fleet`](super::Fleet) keeps one `ServingStats`
//! per device member and merges them for totals.

use super::request::Priority;
use crate::metrics::{Counter, Histogram};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Aggregated serving metrics.
#[derive(Debug, Default)]
pub struct ServingStats {
    /// Requests admitted into the queue.
    pub admitted: Counter,
    /// Requests rejected by backpressure or lack of a route.
    pub rejected: Counter,
    /// Requests completed successfully.
    pub completed: Counter,
    /// Requests failed (backend error).
    pub failed: Counter,
    /// Requests shed after admission because their deadline expired
    /// before execution.
    pub shed: Counter,
    /// Requests cancelled by their ticket before execution.
    pub cancelled: Counter,
    /// Requests this member stole from a hot peer's admission queue
    /// (thief side: executed here, through this member's own router).
    pub steals: Counter,
    /// Requests stolen away from this member's admission queue by an
    /// idle peer (victim side).
    pub stolen: Counter,
    /// Requests declined at submit because no member's queue-aware ETA
    /// fit the deadline budget (`SubmitError::Infeasible`) — recorded
    /// service-side, like the submit-path shed counter.
    pub infeasible: Counter,
    /// Tuned-tile hot swaps applied to this member
    /// ([`FleetController::retune`](super::FleetController::retune)).
    pub retunes: Counter,
    /// Members added by the autoscaler's control loop
    /// ([`Autoscaler`](super::Autoscaler); fleet-level, recorded on the
    /// fleet-local stats, never on a member).
    pub scale_ups: Counter,
    /// Members drained and removed by the autoscaler's control loop
    /// (fleet-level, like `scale_ups`).
    pub scale_downs: Counter,
    /// Whole pending batches this member claimed from a peer's batcher
    /// (thief side; the individual requests are also counted in
    /// `steals`/`stolen`, so `inflight` stays balanced).
    pub migrated_batches: Counter,
    /// Batches executed.
    pub batches: Counter,
    /// Sum of batch sizes (mean batch size = batched / batches).
    pub batched: Counter,
    /// End-to-end latency (admission → reply).
    pub latency: Histogram,
    /// Queue+batch wait (admission → execution start).
    pub queue_wait: Histogram,
    /// Pure execution time per batch.
    pub exec_time: Histogram,
    /// End-to-end latency split by priority class (indexed by
    /// [`Priority::index`]).
    pub latency_by_class: [Histogram; 2],
    /// Queue wait split by priority class.
    pub queue_by_class: [Histogram; 2],
    /// Sampled submit-path time in the snapshot phase (refreshing the
    /// plan pointer + refilling the device-snapshot buffer). Recorded on
    /// the fleet-local stats every `serving.breakdown_sample`-th submit;
    /// see [`ServingStats::submit_breakdown`].
    pub submit_snapshot: Histogram,
    /// Sampled submit-path time in the schedule phase (scheduler pick +
    /// feasibility checks).
    pub submit_schedule: Histogram,
    /// Sampled submit-path time in the admit phase (ticket creation +
    /// admission-policy enqueue).
    pub submit_admit: Histogram,
    /// Accumulated simulated device-time of executed requests, in
    /// nanoseconds — the "aggregate sim cost" a simulated fleet is
    /// judged on (each request costs the sim time of the tile variant
    /// its device routed it to).
    pub sim_cost_ns: Counter,
    /// Metered requests whose cost estimate was non-finite (e.g. an
    /// unlaunchable tile) and therefore contributed NOTHING to
    /// `sim_cost_ns` — a non-zero value means the aggregate undercounts
    /// and must not be compared.
    pub unpriced: Counter,
}

impl ServingStats {
    pub fn new() -> ServingStats {
        ServingStats::default()
    }

    /// Reset every counter and histogram (e.g. after a warmup phase so
    /// the reported numbers measure serving, not first-use compilation).
    pub fn reset(&self) {
        self.admitted.reset();
        self.rejected.reset();
        self.completed.reset();
        self.failed.reset();
        self.shed.reset();
        self.cancelled.reset();
        self.steals.reset();
        self.stolen.reset();
        self.infeasible.reset();
        self.retunes.reset();
        self.scale_ups.reset();
        self.scale_downs.reset();
        self.migrated_batches.reset();
        self.batches.reset();
        self.batched.reset();
        self.latency.reset();
        self.queue_wait.reset();
        self.exec_time.reset();
        for h in &self.latency_by_class {
            h.reset();
        }
        for h in &self.queue_by_class {
            h.reset();
        }
        self.submit_snapshot.reset();
        self.submit_schedule.reset();
        self.submit_admit.reset();
        self.sim_cost_ns.reset();
        self.unpriced.reset();
    }

    /// Add `other`'s counters and histogram contents into `self`
    /// (fleet aggregation; `other` is left untouched).
    pub fn merge_from(&self, other: &ServingStats) {
        self.admitted.add(other.admitted.get());
        self.rejected.add(other.rejected.get());
        self.completed.add(other.completed.get());
        self.failed.add(other.failed.get());
        self.shed.add(other.shed.get());
        self.cancelled.add(other.cancelled.get());
        self.steals.add(other.steals.get());
        self.stolen.add(other.stolen.get());
        self.infeasible.add(other.infeasible.get());
        self.retunes.add(other.retunes.get());
        self.scale_ups.add(other.scale_ups.get());
        self.scale_downs.add(other.scale_downs.get());
        self.migrated_batches.add(other.migrated_batches.get());
        self.batches.add(other.batches.get());
        self.batched.add(other.batched.get());
        self.latency.merge_from(&other.latency);
        self.queue_wait.merge_from(&other.queue_wait);
        self.exec_time.merge_from(&other.exec_time);
        for (mine, theirs) in self.latency_by_class.iter().zip(&other.latency_by_class) {
            mine.merge_from(theirs);
        }
        for (mine, theirs) in self.queue_by_class.iter().zip(&other.queue_by_class) {
            mine.merge_from(theirs);
        }
        self.submit_snapshot.merge_from(&other.submit_snapshot);
        self.submit_schedule.merge_from(&other.submit_schedule);
        self.submit_admit.merge_from(&other.submit_admit);
        self.sim_cost_ns.add(other.sim_cost_ns.get());
        self.unpriced.add(other.unpriced.get());
    }

    /// Record the queue wait of one request about to execute.
    pub fn record_queue_wait(&self, priority: Priority, wait: Duration) {
        self.queue_wait.record(wait);
        self.queue_by_class[priority.index()].record(wait);
    }

    /// Record the end-to-end latency of one answered request.
    pub fn record_latency(&self, priority: Priority, latency: Duration) {
        self.latency.record(latency);
        self.latency_by_class[priority.index()].record(latency);
    }

    /// Record the simulated device-time of one executed request. A
    /// non-finite or negative estimate (unlaunchable tile) cannot be
    /// summed; it is counted in `unpriced` so consumers know the
    /// aggregate is incomplete.
    pub fn record_sim_cost_ms(&self, ms: f64) {
        if ms.is_finite() && ms >= 0.0 {
            self.sim_cost_ns.add((ms * 1e6) as u64);
        } else {
            self.unpriced.inc();
        }
    }

    /// Accumulated simulated cost in milliseconds.
    pub fn sim_cost_ms(&self) -> f64 {
        self.sim_cost_ns.get() as f64 / 1e6
    }

    /// Requests owned by this member and not yet answered — the
    /// scheduler's load signal for this device. Work-stealing moves
    /// ownership: a stolen request leaves the victim's backlog
    /// (`stolen`) and joins the thief's (`steals`).
    pub fn inflight(&self) -> u64 {
        (self.admitted.get() + self.steals.get()).saturating_sub(
            self.completed.get()
                + self.failed.get()
                + self.shed.get()
                + self.cancelled.get()
                + self.stolen.get(),
        )
    }

    /// Mean batch size so far (0 when no batches).
    pub fn mean_batch(&self) -> f64 {
        let b = self.batches.get();
        if b == 0 {
            0.0
        } else {
            self.batched.get() as f64 / b as f64
        }
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "admitted={} rejected={} completed={} failed={} shed={} cancelled={} \
             steals={} stolen={} infeasible={} batches={} mean_batch={:.2} | latency {}",
            self.admitted.get(),
            self.rejected.get(),
            self.completed.get(),
            self.failed.get(),
            self.shed.get(),
            self.cancelled.get(),
            self.steals.get(),
            self.stolen.get(),
            self.infeasible.get(),
            self.batches.get(),
            self.mean_batch(),
            self.latency.summary(),
        )
    }

    /// One-line submit-path time breakdown (p50/p99 per phase) from the
    /// sampled phase histograms, or `None` when no samples were taken
    /// (sampling off, or no submits yet). What `tilekit serve` and the
    /// serving bench print to show where the next submit-path
    /// optimization should go.
    pub fn submit_breakdown(&self) -> Option<String> {
        if self.submit_snapshot.count() == 0 {
            return None;
        }
        let pair = |h: &Histogram| {
            format!("p50={:.1}us p99={:.1}us", h.percentile_us(50.0), h.percentile_us(99.0))
        };
        Some(format!(
            "submit path (n={}): snapshot {} | schedule {} | admit {}",
            self.submit_snapshot.count(),
            pair(&self.submit_snapshot),
            pair(&self.submit_schedule),
            pair(&self.submit_admit),
        ))
    }

    /// Per-priority-class latency report (p50/p95/p99), one line per
    /// class — what `tilekit serve` prints.
    pub fn class_summary(&self) -> String {
        Priority::ALL
            .iter()
            .map(|p| {
                let lat = &self.latency_by_class[p.index()];
                let q = &self.queue_by_class[p.index()];
                format!(
                    "{:<11} n={} queue p50={:.0}us p95={:.0}us p99={:.0}us | \
                     e2e p50={:.0}us p95={:.0}us p99={:.0}us",
                    p.label(),
                    lat.count(),
                    q.percentile_us(50.0),
                    q.percentile_us(95.0),
                    q.percentile_us(99.0),
                    lat.percentile_us(50.0),
                    lat.percentile_us(95.0),
                    lat.percentile_us(99.0),
                )
            })
            .collect::<Vec<_>>()
            .join("\n")
    }
}

/// Monotonic request-id allocator.
#[derive(Debug, Default)]
pub struct IdGen(AtomicU64);

impl IdGen {
    pub fn next(&self) -> u64 {
        self.0.fetch_add(1, Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_batch() {
        let s = ServingStats::new();
        assert_eq!(s.mean_batch(), 0.0);
        s.batches.add(2);
        s.batched.add(6);
        assert_eq!(s.mean_batch(), 3.0);
    }

    #[test]
    fn idgen_unique() {
        let g = IdGen::default();
        let a = g.next();
        let b = g.next();
        assert_ne!(a, b);
    }

    #[test]
    fn summary_contains_counts() {
        let s = ServingStats::new();
        s.admitted.inc();
        s.shed.inc();
        assert!(s.summary().contains("admitted=1"));
        assert!(s.summary().contains("shed=1"));
    }

    #[test]
    fn class_recording_lands_in_the_right_bucket() {
        let s = ServingStats::new();
        s.record_latency(Priority::Interactive, Duration::from_micros(100));
        s.record_latency(Priority::Batch, Duration::from_micros(200));
        s.record_latency(Priority::Batch, Duration::from_micros(300));
        assert_eq!(s.latency.count(), 3);
        assert_eq!(s.latency_by_class[Priority::Interactive.index()].count(), 1);
        assert_eq!(s.latency_by_class[Priority::Batch.index()].count(), 2);
        let report = s.class_summary();
        assert!(report.contains("interactive"));
        assert!(report.contains("batch"));
    }

    #[test]
    fn inflight_accounts_all_outcomes() {
        let s = ServingStats::new();
        s.admitted.add(10);
        s.completed.add(4);
        s.failed.add(1);
        s.shed.add(2);
        s.cancelled.add(1);
        assert_eq!(s.inflight(), 2);
    }

    #[test]
    fn inflight_tracks_stolen_ownership() {
        // Victim: admitted 10, lost 3 to a thief, answered 7 -> drained.
        let victim = ServingStats::new();
        victim.admitted.add(10);
        victim.stolen.add(3);
        victim.completed.add(7);
        assert_eq!(victim.inflight(), 0);
        // Thief: stole 3, completed 2 -> owns 1.
        let thief = ServingStats::new();
        thief.steals.add(3);
        thief.completed.add(2);
        assert_eq!(thief.inflight(), 1);
        // Fleet-wide the merged view still balances: 10 admitted + 3
        // stolen in, 9 answered + 3 stolen away -> 1 in flight.
        let total = ServingStats::new();
        total.merge_from(&victim);
        total.merge_from(&thief);
        assert_eq!(total.inflight(), 1);
        assert_eq!(total.steals.get(), 3);
        assert_eq!(total.stolen.get(), 3);
    }

    #[test]
    fn sim_cost_accumulates_in_ns_and_flags_unpriced() {
        let s = ServingStats::new();
        s.record_sim_cost_ms(0.0033);
        s.record_sim_cost_ms(0.0014);
        s.record_sim_cost_ms(f64::INFINITY); // unsummable
        s.record_sim_cost_ms(f64::NAN); // unsummable
        assert_eq!(s.sim_cost_ns.get(), 3300 + 1400);
        assert!((s.sim_cost_ms() - 0.0047).abs() < 1e-9);
        assert_eq!(s.unpriced.get(), 2, "unsummable costs must be flagged");
    }

    #[test]
    fn scale_and_migration_counters_merge_but_never_enter_inflight() {
        let s = ServingStats::new();
        s.admitted.add(4);
        s.completed.add(4);
        s.scale_ups.add(2);
        s.scale_downs.add(1);
        s.migrated_batches.add(3);
        // Scale events and batch migrations are bookkeeping, not request
        // ownership: the load signal must not move.
        assert_eq!(s.inflight(), 0);
        let total = ServingStats::new();
        total.merge_from(&s);
        total.merge_from(&s);
        assert_eq!(total.scale_ups.get(), 4);
        assert_eq!(total.scale_downs.get(), 2);
        assert_eq!(total.migrated_batches.get(), 6);
        total.reset();
        assert_eq!(total.scale_ups.get(), 0);
        assert_eq!(total.scale_downs.get(), 0);
        assert_eq!(total.migrated_batches.get(), 0);
    }

    #[test]
    fn submit_breakdown_reports_sampled_phases() {
        let s = ServingStats::new();
        assert!(s.submit_breakdown().is_none(), "no samples -> no report");
        s.submit_snapshot.record_us(2.0);
        s.submit_schedule.record_us(1.0);
        s.submit_admit.record_us(5.0);
        let line = s.submit_breakdown().unwrap();
        assert!(line.contains("snapshot"), "{line}");
        assert!(line.contains("schedule"), "{line}");
        assert!(line.contains("admit"), "{line}");
        assert!(line.contains("n=1"), "{line}");
        // Breakdown histograms survive merge and vanish on reset.
        let t = ServingStats::new();
        t.merge_from(&s);
        assert_eq!(t.submit_snapshot.count(), 1);
        assert_eq!(t.submit_admit.count(), 1);
        t.reset();
        assert!(t.submit_breakdown().is_none());
    }

    #[test]
    fn merge_sums_counters_and_histograms() {
        let a = ServingStats::new();
        let b = ServingStats::new();
        a.admitted.add(3);
        b.admitted.add(4);
        b.shed.add(1);
        a.record_latency(Priority::Interactive, Duration::from_micros(50));
        b.record_latency(Priority::Batch, Duration::from_micros(70));
        b.record_sim_cost_ms(1.0);
        a.merge_from(&b);
        assert_eq!(a.admitted.get(), 7);
        assert_eq!(a.shed.get(), 1);
        assert_eq!(a.latency.count(), 2);
        assert_eq!(a.latency_by_class[1].count(), 1);
        assert_eq!(a.sim_cost_ns.get(), 1_000_000);
        // source untouched
        assert_eq!(b.admitted.get(), 4);
    }
}
