//! Serving statistics: request/batch counters and latency histograms,
//! shared (via `Arc`) between the pipeline stages and the caller.

use crate::metrics::{Counter, Histogram};
use std::sync::atomic::{AtomicU64, Ordering};

/// Aggregated serving metrics.
#[derive(Debug, Default)]
pub struct ServingStats {
    /// Requests admitted into the queue.
    pub admitted: Counter,
    /// Requests rejected by backpressure.
    pub rejected: Counter,
    /// Requests completed successfully.
    pub completed: Counter,
    /// Requests failed (backend error).
    pub failed: Counter,
    /// Batches executed.
    pub batches: Counter,
    /// Sum of batch sizes (mean batch size = batched / batches).
    pub batched: Counter,
    /// End-to-end latency (admission → reply).
    pub latency: Histogram,
    /// Queue+batch wait (admission → execution start).
    pub queue_wait: Histogram,
    /// Pure execution time per batch.
    pub exec_time: Histogram,
}

impl ServingStats {
    pub fn new() -> ServingStats {
        ServingStats::default()
    }

    /// Reset every counter and histogram (e.g. after a warmup phase so
    /// the reported numbers measure serving, not first-use compilation).
    pub fn reset(&self) {
        self.admitted.reset();
        self.rejected.reset();
        self.completed.reset();
        self.failed.reset();
        self.batches.reset();
        self.batched.reset();
        self.latency.reset();
        self.queue_wait.reset();
        self.exec_time.reset();
    }

    /// Mean batch size so far (0 when no batches).
    pub fn mean_batch(&self) -> f64 {
        let b = self.batches.get();
        if b == 0 {
            0.0
        } else {
            self.batched.get() as f64 / b as f64
        }
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "admitted={} rejected={} completed={} failed={} batches={} mean_batch={:.2} | latency {}",
            self.admitted.get(),
            self.rejected.get(),
            self.completed.get(),
            self.failed.get(),
            self.batches.get(),
            self.mean_batch(),
            self.latency.summary(),
        )
    }
}

/// Monotonic request-id allocator.
#[derive(Debug, Default)]
pub struct IdGen(AtomicU64);

impl IdGen {
    pub fn next(&self) -> u64 {
        self.0.fetch_add(1, Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_batch() {
        let s = ServingStats::new();
        assert_eq!(s.mean_batch(), 0.0);
        s.batches.add(2);
        s.batched.add(6);
        assert_eq!(s.mean_batch(), 3.0);
    }

    #[test]
    fn idgen_unique() {
        let g = IdGen::default();
        let a = g.next();
        let b = g.next();
        assert_ne!(a, b);
    }

    #[test]
    fn summary_contains_counts() {
        let s = ServingStats::new();
        s.admitted.inc();
        assert!(s.summary().contains("admitted=1"));
    }
}
