//! The autoscaler: a closed-loop capacity controller over the fleet's
//! control plane.
//!
//! PRs 4–6 made tuning adaptive to *hardware* (per-device tiles, the
//! retune daemon); this module makes capacity adaptive to *load*. A
//! background loop samples queue depth, shed/infeasible deltas, and the
//! interactive p99 from [`ServingStats`], feeds them through a pure
//! watermark policy ([`policy::decide`]) — high/low watermarks with
//! hysteresis and a cooldown so it never flaps — and actuates via the
//! live [`FleetController`]:
//!
//! * scale **up**: [`FleetController::add_member`] from a configured
//!   standby-device pool ([`StandbyMember`]), each joining with its own
//!   tuned-tile policy. Peers' batch-migration thieves (see
//!   [`stealing`](super::stealing)) make the new member useful within
//!   one batch window: it claims a victim's whole pending group instead
//!   of waiting for the scheduler to route fresh traffic its way.
//! * scale **down**: [`FleetController::drain`] then
//!   [`FleetController::remove_member`] with [`DrainMode::Graceful`] —
//!   every in-flight ticket still resolves; the member returns to the
//!   standby pool for the next burst.
//!
//! The loop mirrors the [`RetuneDaemon`](super::RetuneDaemon) idiom:
//! spawn/stop/Drop, sliced sleeps so `stop()` returns promptly, exit
//! when the watched fleet shuts down. A cheap [`AutoscalerHandle`]
//! exposes live knobs (enable/disable, watermarks, cooldown) and a
//! [`AutoscalerView`] snapshot — the surface `tilekit fleet autoscaler`
//! drives locally and over the wire.

use super::server::{DrainMode, FleetController};
use super::stats::ServingStats;
use crate::coordinator::request::Priority;
use crate::coordinator::router::TilePolicy;
use crate::device::DeviceDescriptor;
use crate::metrics::Counter;
use crate::runtime::ResizeBackend;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// The pure scaling policy: watermarks, hysteresis, cooldown. No
/// clocks, no threads, no I/O — every decision is a function of the
/// config, the mutable [`PolicyState`], and one [`Sample`], so the
/// no-flap and cooldown invariants are property-testable (see
/// `rust/tests/properties.rs`).
pub mod policy {
    /// Watermark configuration. `low_queue`/`high_queue` are per-member
    /// queue-depth watermarks (queued requests ÷ live members): the band
    /// between them is the hysteresis dead zone where the controller
    /// holds. `high_p99_us == 0` disables the latency trigger (the
    /// served histograms are cumulative, so a past burst would otherwise
    /// pin the signal high).
    #[derive(Debug, Clone, Copy, PartialEq)]
    pub struct PolicyConfig {
        /// Scale down only while queued/members < this.
        pub low_queue: f64,
        /// Scale up once queued/members > this.
        pub high_queue: f64,
        /// Optional scale-up trigger on interactive p99 (µs); 0 = off.
        pub high_p99_us: u64,
        /// Ticks to hold after any scale action (hysteresis in time).
        pub cooldown_ticks: u32,
        /// Never scale below this many members.
        pub min_members: usize,
        /// Never scale above this many members (min + standby pool).
        pub max_members: usize,
    }

    impl Default for PolicyConfig {
        fn default() -> PolicyConfig {
            PolicyConfig {
                low_queue: 1.0,
                high_queue: 8.0,
                high_p99_us: 0,
                cooldown_ticks: 5,
                min_members: 1,
                max_members: 1,
            }
        }
    }

    /// One observation of the fleet, taken per poll tick. The deltas
    /// are since the PREVIOUS tick (the caller differences the
    /// cumulative counters), so a burst of sheds triggers exactly while
    /// it happens, not forever after.
    #[derive(Debug, Clone, Copy, Default)]
    pub struct Sample {
        /// Live (non-draining) members.
        pub members: usize,
        /// Requests waiting in admission queues, fleet-wide.
        pub queued: u64,
        /// Deadline sheds since the last tick.
        pub shed_delta: u64,
        /// Infeasible declines since the last tick.
        pub infeasible_delta: u64,
        /// Interactive-class end-to-end p99, µs (cumulative histogram).
        pub interactive_p99_us: u64,
    }

    /// What the controller should do this tick.
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum Decision {
        Hold,
        /// Engage one standby member.
        ScaleUp,
        /// Drain + gracefully remove the most recently engaged member.
        ScaleDown,
    }

    /// The policy's only memory: how many ticks of cooldown remain.
    #[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
    pub struct PolicyState {
        pub cooldown: u32,
    }

    /// One policy step. Invariants (property-tested):
    ///
    /// * **no-flap**: while per-member pressure stays inside
    ///   `[low_queue, high_queue]` and no sheds/infeasibles arrive (and
    ///   the p99 trigger is quiet), the decision is always `Hold` — an
    ///   oscillating-but-in-band metric stream never changes the target;
    /// * **cooldown monotonicity**: after any non-`Hold` decision, the
    ///   next `cooldown_ticks` calls return `Hold` regardless of input,
    ///   so two scale actions are always ≥ `cooldown_ticks + 1` ticks
    ///   apart;
    /// * **clamping**: `ScaleUp` is never issued at `max_members`,
    ///   `ScaleDown` never at (or below) `min_members`.
    pub fn decide(cfg: &PolicyConfig, state: &mut PolicyState, s: &Sample) -> Decision {
        if state.cooldown > 0 {
            state.cooldown -= 1;
            return Decision::Hold;
        }
        let pressure = s.queued as f64 / s.members.max(1) as f64;
        // Sheds/infeasibles are direct evidence of undercapacity no
        // matter what the queues look like (a short deep burst can shed
        // without ever holding a deep steady-state queue).
        let distress = s.shed_delta > 0 || s.infeasible_delta > 0;
        let hot = pressure > cfg.high_queue
            || distress
            || (cfg.high_p99_us > 0 && s.interactive_p99_us > cfg.high_p99_us);
        // The p99 trigger is deliberately absent from the scale-down
        // side: the histogram is cumulative, so a past burst would pin
        // it and strand capacity engaged forever. Idle is judged on
        // live signals only.
        let cold = pressure < cfg.low_queue && !distress;
        if hot && s.members < cfg.max_members {
            state.cooldown = cfg.cooldown_ticks;
            return Decision::ScaleUp;
        }
        if cold && s.members > cfg.min_members {
            state.cooldown = cfg.cooldown_ticks;
            return Decision::ScaleDown;
        }
        Decision::Hold
    }
}

use policy::{decide, Decision, PolicyConfig, PolicyState, Sample};

/// One parked capacity unit: everything `add_member` needs to engage a
/// device, held ready so scale-up is a control-plane call, not a
/// provisioning workflow.
pub struct StandbyMember {
    pub device: DeviceDescriptor,
    pub backend: Arc<dyn ResizeBackend>,
    /// The tile policy the member's router resolves through when
    /// engaged (`TilePolicy::PerDevice` routes it straight to its tuned
    /// tile — the paper's point, applied at scale-up time).
    pub policy: TilePolicy,
}

/// Live counters of one autoscaler's activity.
#[derive(Debug, Default)]
pub struct AutoscalerStats {
    /// Control-loop ticks (including disabled ones).
    pub ticks: Counter,
    /// Members engaged from the standby pool.
    pub scale_ups: Counter,
    /// Members drained + removed back to the pool.
    pub scale_downs: Counter,
    /// Ticks that sampled and decided `Hold`.
    pub holds: Counter,
    /// Control-plane actuations that failed (the pool entry is
    /// returned/kept, so the loop retries after the cooldown).
    pub errors: Counter,
}

/// Knobs + mirrors shared between the control loop and its handles.
/// Watermarks are stored as `f64` bit patterns so `set` applies
/// atomically mid-tick.
struct Shared {
    enabled: AtomicBool,
    low_bits: AtomicU64,
    high_bits: AtomicU64,
    high_p99_us: AtomicU64,
    cooldown_ticks: AtomicU32,
    poll_ms: u64,
    min_members: usize,
    max_members: usize,
    /// Standby entries currently engaged (mirrored by the loop so
    /// handles can report pool occupancy without touching the pool).
    engaged: AtomicUsize,
    stats: AutoscalerStats,
}

impl Shared {
    fn low(&self) -> f64 {
        f64::from_bits(self.low_bits.load(Ordering::Acquire))
    }

    fn high(&self) -> f64 {
        f64::from_bits(self.high_bits.load(Ordering::Acquire))
    }

    fn policy_config(&self) -> PolicyConfig {
        PolicyConfig {
            low_queue: self.low(),
            high_queue: self.high(),
            high_p99_us: self.high_p99_us.load(Ordering::Acquire),
            cooldown_ticks: self.cooldown_ticks.load(Ordering::Acquire),
            min_members: self.min_members,
            max_members: self.max_members,
        }
    }
}

/// Spawn-time options. `poll` is the sampling interval; the watermark
/// fields seed [`policy::PolicyConfig`] (members bounds are derived:
/// min = the fleet's size at spawn, max = min + standby pool).
#[derive(Debug, Clone, Copy)]
pub struct AutoscalerOpts {
    pub poll: Duration,
    pub low_queue: f64,
    pub high_queue: f64,
    pub high_p99_us: u64,
    pub cooldown_ticks: u32,
    /// Start disabled (`fleet autoscaler enable` arms it later).
    pub start_disabled: bool,
}

impl Default for AutoscalerOpts {
    fn default() -> AutoscalerOpts {
        AutoscalerOpts {
            poll: Duration::from_millis(100),
            low_queue: 1.0,
            high_queue: 8.0,
            high_p99_us: 0,
            cooldown_ticks: 5,
            start_disabled: false,
        }
    }
}

/// A point-in-time snapshot of the controller for status displays and
/// the wire protocol's `AutoscalerDesc` frame.
#[derive(Debug, Clone, PartialEq)]
pub struct AutoscalerView {
    pub enabled: bool,
    pub low_queue: f64,
    pub high_queue: f64,
    pub high_p99_us: u64,
    pub cooldown_ticks: u32,
    pub poll_ms: u64,
    pub min_members: usize,
    pub max_members: usize,
    /// Standby entries currently parked (pool size − engaged).
    pub standby_free: usize,
    pub ticks: u64,
    pub scale_ups: u64,
    pub scale_downs: u64,
    pub holds: u64,
    pub errors: u64,
}

impl AutoscalerView {
    /// One-line status for `tilekit fleet autoscaler status`.
    pub fn summary(&self) -> String {
        format!(
            "autoscaler {} | members {}..={} standby_free={} | low={} high={} \
             cooldown={} poll={}ms | ticks={} ups={} downs={} holds={} errors={}",
            if self.enabled { "enabled" } else { "disabled" },
            self.min_members,
            self.max_members,
            self.standby_free,
            self.low_queue,
            self.high_queue,
            self.cooldown_ticks,
            self.poll_ms,
            self.ticks,
            self.scale_ups,
            self.scale_downs,
            self.holds,
            self.errors,
        )
    }
}

/// A partial reconfiguration, applied atomically by
/// [`AutoscalerHandle::apply`] — the payload of the wire `set_autoscaler`
/// verb and of `tilekit fleet autoscaler set`.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct AutoscalerUpdate {
    pub enabled: Option<bool>,
    pub low_queue: Option<f64>,
    pub high_queue: Option<f64>,
    pub high_p99_us: Option<u64>,
    pub cooldown_ticks: Option<u32>,
}

impl AutoscalerUpdate {
    pub fn is_empty(&self) -> bool {
        *self == AutoscalerUpdate::default()
    }
}

/// Cheap, clonable handle onto a running [`Autoscaler`]: live knobs and
/// status snapshots, without owning the loop. The net server holds one
/// to answer `autoscaler`/`set_autoscaler` frames.
#[derive(Clone)]
pub struct AutoscalerHandle {
    shared: Arc<Shared>,
}

impl AutoscalerHandle {
    /// Snapshot the controller's knobs and counters.
    pub fn view(&self) -> AutoscalerView {
        let s = &self.shared;
        AutoscalerView {
            enabled: s.enabled.load(Ordering::Acquire),
            low_queue: s.low(),
            high_queue: s.high(),
            high_p99_us: s.high_p99_us.load(Ordering::Acquire),
            cooldown_ticks: s.cooldown_ticks.load(Ordering::Acquire),
            poll_ms: s.poll_ms,
            min_members: s.min_members,
            max_members: s.max_members,
            standby_free: (s.max_members - s.min_members)
                .saturating_sub(s.engaged.load(Ordering::Acquire)),
            ticks: s.stats.ticks.get(),
            scale_ups: s.stats.scale_ups.get(),
            scale_downs: s.stats.scale_downs.get(),
            holds: s.stats.holds.get(),
            errors: s.stats.errors.get(),
        }
    }

    /// Arm or pause the control loop (a paused loop keeps ticking but
    /// never samples or actuates, so re-enabling starts from fresh
    /// deltas).
    pub fn set_enabled(&self, enabled: bool) {
        self.shared.enabled.store(enabled, Ordering::Release);
    }

    /// Apply a partial reconfiguration after validating the RESULTING
    /// knob set (so `set low=9` against `high=8` is rejected instead of
    /// inverting the band).
    pub fn apply(&self, update: &AutoscalerUpdate) -> anyhow::Result<()> {
        let low = update.low_queue.unwrap_or_else(|| self.shared.low());
        let high = update.high_queue.unwrap_or_else(|| self.shared.high());
        if !low.is_finite() || !high.is_finite() || low < 0.0 {
            anyhow::bail!("autoscaler watermarks must be finite and non-negative");
        }
        if low >= high {
            anyhow::bail!("autoscaler low watermark must be < high (got {low} >= {high})");
        }
        self.shared.low_bits.store(low.to_bits(), Ordering::Release);
        self.shared
            .high_bits
            .store(high.to_bits(), Ordering::Release);
        if let Some(p99) = update.high_p99_us {
            self.shared.high_p99_us.store(p99, Ordering::Release);
        }
        if let Some(cd) = update.cooldown_ticks {
            self.shared.cooldown_ticks.store(cd, Ordering::Release);
        }
        if let Some(e) = update.enabled {
            self.set_enabled(e);
        }
        Ok(())
    }
}

/// The running control loop. Spawn with [`Autoscaler::spawn`]; the
/// thread exits on [`stop`](Autoscaler::stop), on drop, or when the
/// watched fleet shuts down.
pub struct Autoscaler {
    stop: Arc<AtomicBool>,
    shared: Arc<Shared>,
    handle: Option<JoinHandle<()>>,
}

impl Autoscaler {
    /// Start the loop over `controller`, with `standby` as the capacity
    /// pool. The fleet's CURRENT live-member count becomes the floor
    /// (`min_members`); floor + pool size the ceiling. Standby labels
    /// must not collide with serving members — `remove_member` removes
    /// by label, so a collision would take the base fleet down with the
    /// burst capacity (config validation enforces this for the CLI
    /// path).
    pub fn spawn(
        controller: FleetController,
        standby: Vec<StandbyMember>,
        opts: AutoscalerOpts,
    ) -> anyhow::Result<Autoscaler> {
        if standby.is_empty() {
            anyhow::bail!("autoscaler needs a non-empty standby pool");
        }
        if !opts.low_queue.is_finite() || !opts.high_queue.is_finite() || opts.low_queue < 0.0 {
            anyhow::bail!("autoscaler watermarks must be finite and non-negative");
        }
        if opts.low_queue >= opts.high_queue {
            anyhow::bail!(
                "autoscaler low watermark must be < high (got {} >= {})",
                opts.low_queue,
                opts.high_queue
            );
        }
        if opts.poll.is_zero() {
            anyhow::bail!("autoscaler poll interval must be > 0");
        }
        let base = controller
            .topology()
            .members
            .iter()
            .filter(|m| !m.draining)
            .count();
        if base == 0 {
            anyhow::bail!("autoscaler needs a fleet with at least one live member");
        }
        for sb in &standby {
            if controller
                .topology()
                .members
                .iter()
                .any(|m| &*m.label == sb.device.id.as_str())
            {
                anyhow::bail!(
                    "standby device '{}' is already a fleet member",
                    sb.device.id
                );
            }
        }
        let shared = Arc::new(Shared {
            enabled: AtomicBool::new(!opts.start_disabled),
            low_bits: AtomicU64::new(opts.low_queue.to_bits()),
            high_bits: AtomicU64::new(opts.high_queue.to_bits()),
            high_p99_us: AtomicU64::new(opts.high_p99_us),
            cooldown_ticks: AtomicU32::new(opts.cooldown_ticks),
            poll_ms: opts.poll.as_millis() as u64,
            min_members: base,
            max_members: base + standby.len(),
            engaged: AtomicUsize::new(0),
            stats: AutoscalerStats::default(),
        });
        let stop = Arc::new(AtomicBool::new(false));
        let handle = {
            let stop = Arc::clone(&stop);
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("tilekit-autoscaler".into())
                .spawn(move || run_autoscaler(controller, standby, opts.poll, &stop, &shared))
                .expect("spawn autoscaler")
        };
        Ok(Autoscaler {
            stop,
            shared,
            handle: Some(handle),
        })
    }

    /// A cheap handle for status/reconfiguration (e.g. to hand to the
    /// net server).
    pub fn handle(&self) -> AutoscalerHandle {
        AutoscalerHandle {
            shared: Arc::clone(&self.shared),
        }
    }

    /// The loop's live activity counters.
    pub fn stats(&self) -> &AutoscalerStats {
        &self.shared.stats
    }

    /// Stop the loop and join its thread. Engaged standby members stay
    /// in the fleet (stopping the controller must not shrink capacity
    /// under live traffic).
    pub fn stop(mut self) {
        self.stop_inner();
    }

    fn stop_inner(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Autoscaler {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

fn run_autoscaler(
    controller: FleetController,
    mut standby: Vec<StandbyMember>,
    poll: Duration,
    stop: &AtomicBool,
    shared: &Shared,
) {
    // Scale events belong to the fleet, not to any one member — they
    // are recorded on the fleet-local stats so `Fleet::stats()` (and
    // the wire's merged WireStats) carries them.
    let fleet_stats: Arc<ServingStats> = controller.local_stats();
    // Engaged pool entries, most recent last: scale-down pops the
    // newest engagement (LIFO), so long-running base members are never
    // the ones churned.
    let mut engaged: Vec<StandbyMember> = Vec::new();
    let mut pstate = PolicyState::default();
    let mut last_shed = 0u64;
    let mut last_infeasible = 0u64;
    let mut primed = false;
    // Sleep in short slices so stop() returns promptly even with a
    // long poll interval.
    let slice = poll.min(Duration::from_millis(50)).max(Duration::from_millis(1));
    let mut since_poll = poll; // sample immediately on startup
    while !stop.load(Ordering::Acquire) && !controller.is_closed() {
        if since_poll < poll {
            std::thread::sleep(slice);
            since_poll += slice;
            continue;
        }
        since_poll = Duration::ZERO;
        shared.stats.ticks.inc();
        if !shared.enabled.load(Ordering::Acquire) {
            // Paused: forget the delta baseline so re-enabling does not
            // interpret everything shed meanwhile as a fresh burst.
            primed = false;
            continue;
        }
        let topo = controller.topology();
        let live: Vec<_> = topo.members.iter().filter(|m| !m.draining).collect();
        let stats = controller.stats();
        let shed = stats.shed.get();
        let infeasible = stats.infeasible.get();
        if !primed {
            last_shed = shed;
            last_infeasible = infeasible;
            primed = true;
            continue;
        }
        let sample = Sample {
            members: live.len(),
            queued: live.iter().map(|m| m.queued).sum(),
            shed_delta: shed.saturating_sub(last_shed),
            infeasible_delta: infeasible.saturating_sub(last_infeasible),
            interactive_p99_us: stats.latency_by_class[Priority::Interactive.index()]
                .percentile_us(99.0) as u64,
        };
        last_shed = shed;
        last_infeasible = infeasible;
        let cfg = shared.policy_config();
        match decide(&cfg, &mut pstate, &sample) {
            Decision::Hold => {
                shared.stats.holds.inc();
            }
            Decision::ScaleUp => {
                let Some(sb) = standby.pop() else {
                    // Policy clamps at max_members, so an empty pool
                    // here means an earlier actuation failed; count it
                    // and keep holding.
                    shared.stats.errors.inc();
                    continue;
                };
                match controller.add_member(
                    sb.device.clone(),
                    Arc::clone(&sb.backend),
                    sb.policy.clone(),
                ) {
                    Ok(_) => {
                        engaged.push(sb);
                        shared.engaged.store(engaged.len(), Ordering::Release);
                        shared.stats.scale_ups.inc();
                        fleet_stats.scale_ups.inc();
                    }
                    Err(_) => {
                        standby.push(sb);
                        shared.stats.errors.inc();
                    }
                }
            }
            Decision::ScaleDown => {
                let Some(sb) = engaged.pop() else {
                    shared.stats.errors.inc();
                    continue;
                };
                let label = sb.device.id.clone();
                // Graceful by construction: drain stops the scheduler
                // picking it, remove lets its pipeline (and peer
                // thieves) finish everything already owned — zero lost
                // tickets across the scale event.
                let res = controller
                    .drain(&label)
                    .and_then(|_| controller.remove_member(&label, DrainMode::Graceful));
                match res {
                    Ok(()) => {
                        standby.push(sb);
                        shared.engaged.store(engaged.len(), Ordering::Release);
                        shared.stats.scale_downs.inc();
                        fleet_stats.scale_downs.inc();
                    }
                    Err(_) => {
                        engaged.push(sb);
                        shared.stats.errors.inc();
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::policy::*;
    use super::*;
    use crate::config::ServingConfig;
    use crate::coordinator::{FleetBuilder, Request, TilePolicy};
    use crate::device::find_device;
    use crate::image::{generate, Interpolator};
    use crate::runtime::{Manifest, MockEngine};
    use std::time::Instant;

    fn cfg() -> PolicyConfig {
        PolicyConfig {
            low_queue: 1.0,
            high_queue: 4.0,
            high_p99_us: 0,
            cooldown_ticks: 3,
            min_members: 1,
            max_members: 3,
        }
    }

    fn sample(members: usize, queued: u64) -> Sample {
        Sample {
            members,
            queued,
            ..Sample::default()
        }
    }

    #[test]
    fn scales_up_over_high_watermark_and_down_under_low() {
        let c = cfg();
        let mut st = PolicyState::default();
        assert_eq!(decide(&c, &mut st, &sample(1, 5)), Decision::ScaleUp);
        st = PolicyState::default();
        assert_eq!(decide(&c, &mut st, &sample(2, 1)), Decision::ScaleDown);
    }

    #[test]
    fn holds_inside_the_band_edges_included() {
        let c = cfg();
        let mut st = PolicyState::default();
        // pressure exactly at the watermarks is the dead zone.
        assert_eq!(decide(&c, &mut st, &sample(2, 8)), Decision::Hold); // 4.0
        assert_eq!(decide(&c, &mut st, &sample(2, 2)), Decision::Hold); // 1.0
        assert_eq!(st.cooldown, 0, "holds never start a cooldown");
    }

    #[test]
    fn cooldown_blocks_decisions_then_releases() {
        let c = cfg();
        let mut st = PolicyState::default();
        assert_eq!(decide(&c, &mut st, &sample(1, 100)), Decision::ScaleUp);
        for _ in 0..c.cooldown_ticks {
            assert_eq!(decide(&c, &mut st, &sample(2, 100)), Decision::Hold);
        }
        assert_eq!(decide(&c, &mut st, &sample(2, 100)), Decision::ScaleUp);
    }

    #[test]
    fn clamps_at_member_bounds() {
        let c = cfg();
        let mut st = PolicyState::default();
        assert_eq!(
            decide(&c, &mut st, &sample(c.max_members, 1000)),
            Decision::Hold,
            "no scale-up past the standby pool"
        );
        assert_eq!(
            decide(&c, &mut st, &sample(c.min_members, 0)),
            Decision::Hold,
            "no scale-down below the base fleet"
        );
        assert_eq!(st.cooldown, 0, "clamped decisions start no cooldown");
    }

    #[test]
    fn distress_triggers_scale_up_even_with_shallow_queues() {
        let c = cfg();
        let mut st = PolicyState::default();
        let s = Sample {
            members: 1,
            queued: 0,
            shed_delta: 2,
            ..Sample::default()
        };
        assert_eq!(decide(&c, &mut st, &s), Decision::ScaleUp);
        // ...and suppresses scale-down even under the low watermark.
        st = PolicyState::default();
        let s = Sample {
            members: 3,
            queued: 0,
            infeasible_delta: 1,
            ..Sample::default()
        };
        assert_ne!(decide(&c, &mut st, &s), Decision::ScaleDown);
    }

    #[test]
    fn p99_trigger_respects_the_disable_sentinel() {
        let mut c = cfg();
        let mut st = PolicyState::default();
        let slow = Sample {
            members: 1,
            queued: 0,
            interactive_p99_us: 1_000_000,
            ..Sample::default()
        };
        assert_eq!(
            decide(&c, &mut st, &slow),
            Decision::ScaleDown,
            "p99 ignored while the trigger is 0 (idle queues win)"
        );
        c.high_p99_us = 10_000;
        st = PolicyState::default();
        assert_eq!(decide(&c, &mut st, &slow), Decision::ScaleUp);
    }

    #[test]
    fn handle_apply_validates_the_resulting_band() {
        let serving = ServingConfig {
            workers: 1,
            batch_max: Some(2),
            ..ServingConfig::default()
        };
        let fleet = FleetBuilder::new(&serving, &Manifest::fleet_demo())
            .device(
                find_device("gtx260").unwrap(),
                Arc::new(MockEngine::new()),
                TilePolicy::PortableFallback,
            )
            .build()
            .unwrap();
        let scaler = Autoscaler::spawn(
            fleet.controller(),
            vec![StandbyMember {
                device: find_device("fermi").unwrap(),
                backend: Arc::new(MockEngine::new()),
                policy: TilePolicy::PortableFallback,
            }],
            AutoscalerOpts {
                start_disabled: true,
                ..AutoscalerOpts::default()
            },
        )
        .unwrap();
        let h = scaler.handle();
        let v = h.view();
        assert!(!v.enabled);
        assert_eq!((v.min_members, v.max_members), (1, 2));
        assert_eq!(v.standby_free, 1);
        // Inverting the band is rejected; the knobs stay put.
        assert!(h
            .apply(&AutoscalerUpdate {
                low_queue: Some(9.0),
                ..AutoscalerUpdate::default()
            })
            .is_err());
        assert_eq!(h.view().low_queue, v.low_queue);
        h.apply(&AutoscalerUpdate {
            enabled: Some(true),
            low_queue: Some(0.5),
            high_queue: Some(6.0),
            cooldown_ticks: Some(9),
            ..AutoscalerUpdate::default()
        })
        .unwrap();
        let v = h.view();
        assert!(v.enabled);
        assert_eq!(v.low_queue, 0.5);
        assert_eq!(v.high_queue, 6.0);
        assert_eq!(v.cooldown_ticks, 9);
        scaler.stop();
        fleet.shutdown();
    }

    #[test]
    fn spawn_rejects_bad_pools_and_bands() {
        let serving = ServingConfig::default();
        let fleet = FleetBuilder::new(&serving, &Manifest::fleet_demo())
            .device(
                find_device("gtx260").unwrap(),
                Arc::new(MockEngine::new()),
                TilePolicy::PortableFallback,
            )
            .build()
            .unwrap();
        assert!(
            Autoscaler::spawn(fleet.controller(), Vec::new(), AutoscalerOpts::default())
                .is_err(),
            "empty standby pool"
        );
        let dup = vec![StandbyMember {
            device: find_device("gtx260").unwrap(),
            backend: Arc::new(MockEngine::new()),
            policy: TilePolicy::PortableFallback,
        }];
        assert!(
            Autoscaler::spawn(fleet.controller(), dup, AutoscalerOpts::default()).is_err(),
            "standby label colliding with a live member"
        );
        let pool = || {
            vec![StandbyMember {
                device: find_device("fermi").unwrap(),
                backend: Arc::new(MockEngine::new()),
                policy: TilePolicy::PortableFallback,
            }]
        };
        assert!(
            Autoscaler::spawn(
                fleet.controller(),
                pool(),
                AutoscalerOpts {
                    low_queue: 5.0,
                    high_queue: 2.0,
                    ..AutoscalerOpts::default()
                }
            )
            .is_err(),
            "inverted band"
        );
        fleet.shutdown();
    }

    #[test]
    fn scales_up_under_pressure_and_back_down_when_idle() {
        let serving = ServingConfig {
            workers: 1,
            batch_max: Some(2),
            queue_cap: 512,
            ..ServingConfig::default()
        };
        let fleet = FleetBuilder::new(&serving, &Manifest::fleet_demo())
            .device(
                find_device("gtx260").unwrap(),
                Arc::new(MockEngine::with_delay(Duration::from_millis(2))),
                TilePolicy::PortableFallback,
            )
            .build()
            .unwrap();
        let ctl = fleet.controller();
        let scaler = Autoscaler::spawn(
            ctl.clone(),
            vec![StandbyMember {
                device: find_device("fermi").unwrap(),
                backend: Arc::new(MockEngine::with_delay(Duration::from_millis(2))),
                policy: TilePolicy::PortableFallback,
            }],
            AutoscalerOpts {
                poll: Duration::from_millis(2),
                low_queue: 0.5,
                high_queue: 3.0,
                cooldown_ticks: 2,
                ..AutoscalerOpts::default()
            },
        )
        .unwrap();
        let img = generate::gradient(64, 64);
        let tickets: Vec<_> = (0..64)
            .filter_map(|_| {
                fleet
                    .submit(Request::new(Interpolator::Bilinear, img.clone(), 2))
                    .ok()
            })
            .collect();
        assert!(!tickets.is_empty());
        let wait_for = |pred: &dyn Fn() -> bool, what: &str| {
            let deadline = Instant::now() + Duration::from_secs(10);
            while !pred() {
                assert!(Instant::now() < deadline, "timed out waiting for {what}");
                std::thread::sleep(Duration::from_millis(5));
            }
        };
        wait_for(&|| ctl.topology().members.len() == 2, "scale-up");
        for t in tickets {
            t.wait().unwrap();
        }
        wait_for(&|| ctl.topology().members.len() == 1, "scale-down");
        let view = scaler.handle().view();
        assert!(view.scale_ups >= 1 && view.scale_downs >= 1);
        assert_eq!(view.standby_free, 1, "pool entry returned after drain");
        scaler.stop();
        let stats = fleet.shutdown();
        assert!(stats.scale_ups.get() >= 1, "mirrored into fleet stats");
        assert!(stats.scale_downs.get() >= 1);
    }
}
