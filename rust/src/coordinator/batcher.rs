//! The dynamic batcher: accumulates admitted requests per
//! [`RequestKey`], flushing a batch when it reaches the configured size
//! or when the oldest member hits its deadline — the same size+deadline
//! policy inference servers use.
//!
//! The batcher is written as a pure state machine ([`BatcherState`]) so
//! its invariants are property-testable without threads; the server
//! wraps it in a thread that owns the admission queue.

use super::request::{RequestKey, ResizeRequest};
use std::collections::HashMap;
use std::time::{Duration, Instant};

/// A flushed batch headed to the worker pool.
pub struct Batch {
    pub key: RequestKey,
    pub requests: Vec<ResizeRequest>,
}

/// Why [`BatcherState::sweep`] removed a pending request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Shed {
    /// The caller's ticket cancelled it before batch pickup.
    Cancelled,
    /// Its latency budget expired before execution.
    DeadlineExceeded,
}

/// Pure batching state machine.
pub struct BatcherState {
    batch_max: usize,
    deadline: Duration,
    pending: HashMap<RequestKey, Vec<ResizeRequest>>,
}

impl BatcherState {
    pub fn new(batch_max: usize, deadline: Duration) -> BatcherState {
        assert!(batch_max >= 1);
        BatcherState {
            batch_max,
            deadline,
            pending: HashMap::new(),
        }
    }

    /// Admit one request; returns a full batch if this admission filled
    /// one.
    pub fn push(&mut self, req: ResizeRequest) -> Option<Batch> {
        let key = req.key;
        let slot = self.pending.entry(key).or_default();
        slot.push(req);
        if slot.len() >= self.batch_max {
            let requests = std::mem::take(slot);
            self.pending.remove(&key);
            Some(Batch { key, requests })
        } else {
            None
        }
    }

    /// Flush every group whose oldest request has waited ≥ deadline.
    pub fn flush_expired(&mut self, now: Instant) -> Vec<Batch> {
        let expired: Vec<RequestKey> = self
            .pending
            .iter()
            .filter(|(_, reqs)| {
                reqs.first()
                    .map(|r| now.duration_since(r.admitted) >= self.deadline)
                    .unwrap_or(false)
            })
            .map(|(k, _)| *k)
            .collect();
        expired
            .into_iter()
            .filter_map(|key| {
                self.pending.remove(&key).map(|requests| Batch { key, requests })
            })
            .collect()
    }

    /// Remove pending requests that are cancelled or past their
    /// deadline, returning them with the reason. The batcher thread
    /// calls this every poll so a cancelled or expired request never
    /// reaches a worker; the server replies to each with the matching
    /// error.
    pub fn sweep(&mut self, now: Instant) -> Vec<(ResizeRequest, Shed)> {
        let mut shed = Vec::new();
        for reqs in self.pending.values_mut() {
            let mut i = 0;
            while i < reqs.len() {
                let cancelled = reqs[i].is_cancelled();
                let expired = reqs[i].is_expired(now);
                if cancelled || expired {
                    let reason = if cancelled {
                        Shed::Cancelled
                    } else {
                        Shed::DeadlineExceeded
                    };
                    shed.push((reqs.remove(i), reason));
                } else {
                    i += 1;
                }
            }
        }
        self.pending.retain(|_, reqs| !reqs.is_empty());
        shed
    }

    /// Flush everything (shutdown).
    pub fn flush_all(&mut self) -> Vec<Batch> {
        self.pending
            .drain()
            .map(|(key, requests)| Batch { key, requests })
            .collect()
    }

    /// Time until the next deadline expiry (None when idle) — the
    /// batcher thread's poll timeout.
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.pending
            .values()
            .filter_map(|reqs| reqs.first())
            .map(|r| {
                let age = now.duration_since(r.admitted);
                self.deadline.saturating_sub(age)
            })
            .min()
    }

    /// Requests currently held.
    pub fn pending_len(&self) -> usize {
        self.pending.values().map(Vec::len).sum()
    }

    /// Snapshot of the pending table as migration candidates: one
    /// [`MigrationGroup`](super::stealing::MigrationGroup) per key,
    /// counting only live requests, in a deterministic (key-sorted)
    /// order so [`select_batch_migration`]'s tie-break is stable. A
    /// thief calls this under the victim's pending lock.
    pub fn migration_groups(&self, now: Instant) -> Vec<super::stealing::MigrationGroup> {
        let mut groups: Vec<_> = self
            .pending
            .iter()
            .map(|(key, reqs)| super::stealing::MigrationGroup {
                key: *key,
                live: reqs
                    .iter()
                    .filter(|r| !r.is_cancelled() && !r.is_expired(now))
                    .count(),
            })
            .collect();
        groups.sort_by_key(|g| (g.key.kernel as u8, g.key.src, g.key.scale));
        groups
    }

    /// Remove one whole pending group — the extraction half of a batch
    /// migration. Returns every request under `key` (the caller splits
    /// live from cancelled/expired so the dead ones are shed with the
    /// victim's accounting, exactly like [`sweep`](Self::sweep)).
    pub fn take_group(&mut self, key: &RequestKey) -> Vec<ResizeRequest> {
        self.pending.remove(key).unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Ticket;
    use crate::image::{generate, Interpolator};

    fn req(scale: u32) -> ResizeRequest {
        let img = generate::gradient(16, 16);
        let (_t, tx) = Ticket::new(0);
        ResizeRequest::bare(
            0,
            RequestKey::of(Interpolator::Bilinear, &img, scale),
            img,
            tx,
        )
    }

    #[test]
    fn fills_at_batch_max() {
        let mut b = BatcherState::new(3, Duration::from_secs(10));
        assert!(b.push(req(2)).is_none());
        assert!(b.push(req(2)).is_none());
        let batch = b.push(req(2)).expect("full batch");
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    fn keys_batch_separately() {
        let mut b = BatcherState::new(2, Duration::from_secs(10));
        assert!(b.push(req(2)).is_none());
        assert!(b.push(req(4)).is_none());
        assert_eq!(b.pending_len(), 2);
        let batch = b.push(req(2)).expect("scale-2 batch fills");
        assert_eq!(batch.key.scale, 2);
        assert_eq!(b.pending_len(), 1);
    }

    #[test]
    fn deadline_flush() {
        let mut b = BatcherState::new(100, Duration::from_millis(5));
        b.push(req(2));
        b.push(req(4));
        assert!(b.flush_expired(Instant::now()).is_empty());
        let later = Instant::now() + Duration::from_millis(50);
        let flushed = b.flush_expired(later);
        assert_eq!(flushed.len(), 2);
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    fn next_deadline_counts_down() {
        let mut b = BatcherState::new(100, Duration::from_millis(100));
        assert!(b.next_deadline(Instant::now()).is_none());
        b.push(req(2));
        let d = b.next_deadline(Instant::now()).unwrap();
        assert!(d <= Duration::from_millis(100));
        let far = Instant::now() + Duration::from_secs(1);
        assert_eq!(b.next_deadline(far).unwrap(), Duration::ZERO);
    }

    #[test]
    fn sweep_removes_cancelled_and_expired() {
        let mut b = BatcherState::new(100, Duration::from_secs(10));
        let cancelled = req(2);
        let token = cancelled.cancel.clone();
        b.push(cancelled);
        let mut expiring = req(2);
        expiring.deadline = Some(Instant::now() + Duration::from_millis(1));
        b.push(expiring);
        b.push(req(4)); // healthy
        assert!(b.sweep(Instant::now()).is_empty(), "nothing shed yet");
        token.cancel();
        let later = Instant::now() + Duration::from_millis(50);
        let mut shed = b.sweep(later);
        shed.sort_by_key(|(_, r)| *r == Shed::DeadlineExceeded);
        assert_eq!(shed.len(), 2);
        assert_eq!(shed[0].1, Shed::Cancelled);
        assert_eq!(shed[1].1, Shed::DeadlineExceeded);
        assert_eq!(b.pending_len(), 1, "healthy request survives the sweep");
    }

    #[test]
    fn migration_groups_count_live_only_and_take_group_empties_the_key() {
        let mut b = BatcherState::new(100, Duration::from_secs(10));
        b.push(req(2));
        b.push(req(2));
        let cancelled = req(2);
        cancelled.cancel.cancel();
        b.push(cancelled);
        b.push(req(4));
        let now = Instant::now();
        let groups = b.migration_groups(now);
        assert_eq!(groups.len(), 2);
        let live_of = |scale| {
            groups
                .iter()
                .find(|g| g.key.scale == scale)
                .map(|g| g.live)
                .unwrap()
        };
        assert_eq!(live_of(2), 2, "cancelled request must not count as live");
        assert_eq!(live_of(4), 1);
        // Deterministic order across calls (sorted, not HashMap order).
        assert_eq!(b.migration_groups(now), groups);

        let key2 = groups.iter().find(|g| g.key.scale == 2).unwrap().key;
        let taken = b.take_group(&key2);
        assert_eq!(taken.len(), 3, "extraction returns the WHOLE group");
        assert_eq!(b.pending_len(), 1, "other groups untouched");
        assert!(b.take_group(&key2).is_empty(), "second take finds nothing");
    }

    #[test]
    fn flush_all_drains() {
        let mut b = BatcherState::new(100, Duration::from_secs(10));
        b.push(req(2));
        b.push(req(4));
        b.push(req(6));
        let all = b.flush_all();
        let total: usize = all.iter().map(|b| b.requests.len()).sum();
        assert_eq!(total, 3);
        assert_eq!(b.pending_len(), 0);
    }
}
