//! The dynamic batcher: accumulates admitted requests per
//! [`RequestKey`], flushing a batch when it reaches the configured size
//! or when the oldest member hits its deadline — the same size+deadline
//! policy inference servers use.
//!
//! The batcher is written as a pure state machine ([`BatcherState`]) so
//! its invariants are property-testable without threads; the server
//! wraps it in a thread that owns the admission queue.

use super::request::{RequestKey, ResizeRequest};
use std::collections::HashMap;
use std::time::{Duration, Instant};

/// A flushed batch headed to the worker pool.
pub struct Batch {
    pub key: RequestKey,
    pub requests: Vec<ResizeRequest>,
}

/// Pure batching state machine.
pub struct BatcherState {
    batch_max: usize,
    deadline: Duration,
    pending: HashMap<RequestKey, Vec<ResizeRequest>>,
}

impl BatcherState {
    pub fn new(batch_max: usize, deadline: Duration) -> BatcherState {
        assert!(batch_max >= 1);
        BatcherState {
            batch_max,
            deadline,
            pending: HashMap::new(),
        }
    }

    /// Admit one request; returns a full batch if this admission filled
    /// one.
    pub fn push(&mut self, req: ResizeRequest) -> Option<Batch> {
        let key = req.key;
        let slot = self.pending.entry(key).or_default();
        slot.push(req);
        if slot.len() >= self.batch_max {
            let requests = std::mem::take(slot);
            self.pending.remove(&key);
            Some(Batch { key, requests })
        } else {
            None
        }
    }

    /// Flush every group whose oldest request has waited ≥ deadline.
    pub fn flush_expired(&mut self, now: Instant) -> Vec<Batch> {
        let expired: Vec<RequestKey> = self
            .pending
            .iter()
            .filter(|(_, reqs)| {
                reqs.first()
                    .map(|r| now.duration_since(r.admitted) >= self.deadline)
                    .unwrap_or(false)
            })
            .map(|(k, _)| *k)
            .collect();
        expired
            .into_iter()
            .filter_map(|key| {
                self.pending.remove(&key).map(|requests| Batch { key, requests })
            })
            .collect()
    }

    /// Flush everything (shutdown).
    pub fn flush_all(&mut self) -> Vec<Batch> {
        self.pending
            .drain()
            .map(|(key, requests)| Batch { key, requests })
            .collect()
    }

    /// Time until the next deadline expiry (None when idle) — the
    /// batcher thread's poll timeout.
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.pending
            .values()
            .filter_map(|reqs| reqs.first())
            .map(|r| {
                let age = now.duration_since(r.admitted);
                self.deadline.saturating_sub(age)
            })
            .min()
    }

    /// Requests currently held.
    pub fn pending_len(&self) -> usize {
        self.pending.values().map(Vec::len).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Ticket;
    use crate::image::{generate, Interpolator};

    fn req(scale: u32) -> ResizeRequest {
        let img = generate::gradient(16, 16);
        let (_t, tx) = Ticket::new(0);
        ResizeRequest {
            id: 0,
            key: RequestKey::of(Interpolator::Bilinear, &img, scale),
            image: img,
            admitted: Instant::now(),
            reply: tx,
        }
    }

    #[test]
    fn fills_at_batch_max() {
        let mut b = BatcherState::new(3, Duration::from_secs(10));
        assert!(b.push(req(2)).is_none());
        assert!(b.push(req(2)).is_none());
        let batch = b.push(req(2)).expect("full batch");
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    fn keys_batch_separately() {
        let mut b = BatcherState::new(2, Duration::from_secs(10));
        assert!(b.push(req(2)).is_none());
        assert!(b.push(req(4)).is_none());
        assert_eq!(b.pending_len(), 2);
        let batch = b.push(req(2)).expect("scale-2 batch fills");
        assert_eq!(batch.key.scale, 2);
        assert_eq!(b.pending_len(), 1);
    }

    #[test]
    fn deadline_flush() {
        let mut b = BatcherState::new(100, Duration::from_millis(5));
        b.push(req(2));
        b.push(req(4));
        assert!(b.flush_expired(Instant::now()).is_empty());
        let later = Instant::now() + Duration::from_millis(50);
        let flushed = b.flush_expired(later);
        assert_eq!(flushed.len(), 2);
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    fn next_deadline_counts_down() {
        let mut b = BatcherState::new(100, Duration::from_millis(100));
        assert!(b.next_deadline(Instant::now()).is_none());
        b.push(req(2));
        let d = b.next_deadline(Instant::now()).unwrap();
        assert!(d <= Duration::from_millis(100));
        let far = Instant::now() + Duration::from_secs(1);
        assert_eq!(b.next_deadline(far).unwrap(), Duration::ZERO);
    }

    #[test]
    fn flush_all_drains() {
        let mut b = BatcherState::new(100, Duration::from_secs(10));
        b.push(req(2));
        b.push(req(4));
        b.push(req(6));
        let all = b.flush_all();
        let total: usize = all.iter().map(|b| b.requests.len()).sum();
        assert_eq!(total, 3);
        assert_eq!(b.pending_len(), 0);
    }
}
