//! Admission control: what happens when a request meets a full queue.
//!
//! The old coordinator hardcoded two behaviors — `submit` (try_send,
//! fail on full) and `submit_blocking` (a 200µs sleep/retry loop). The
//! [`AdmissionPolicy`] trait replaces both with a pluggable decision,
//! and the sleep loop is gone: [`BlockWithTimeout`] parks on the
//! channel's `not_full` condvar via
//! [`Sender::send_timeout`](crate::exec::Sender::send_timeout).

use super::request::{Priority, ResizeRequest};
use super::server::SubmitError;
use crate::exec::{SendTimeoutError, Sender, TrySendError};
use anyhow::{bail, Result};
use std::time::{Duration, Instant};

/// Decides whether (and how long) a request may wait for queue space on
/// the member the scheduler picked.
pub trait AdmissionPolicy: Send + Sync {
    /// Try to enqueue `req` on `tx`. On error the request is dropped
    /// (its ticket will observe the submit error instead).
    fn admit(&self, tx: &Sender<ResizeRequest>, req: ResizeRequest) -> Result<(), SubmitError>;

    /// Label for reports and `tilekit serve` output.
    fn name(&self) -> &'static str;
}

/// Non-blocking admission: a full queue fails fast with
/// [`SubmitError::Saturated`] (the open-loop replay driver's contract —
/// backpressure must be *recorded*, never absorbed).
#[derive(Debug, Default)]
pub struct RejectWhenFull;

impl AdmissionPolicy for RejectWhenFull {
    fn admit(&self, tx: &Sender<ResizeRequest>, req: ResizeRequest) -> Result<(), SubmitError> {
        match tx.try_send(req) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(_)) => Err(SubmitError::Saturated),
            Err(TrySendError::Disconnected(_)) => Err(SubmitError::ShuttingDown),
        }
    }

    fn name(&self) -> &'static str {
        "reject"
    }
}

/// Blocking admission: wait for queue space up to the timeout, then
/// report [`SubmitError::Saturated`]. This is the closed-loop driver's
/// policy (the old `submit_blocking`, minus the busy-wait). The wait is
/// additionally capped by the request's own latency budget — blocking a
/// caller past its deadline would only hand back a doomed ticket, so an
/// exhausted budget reports [`SubmitError::DeadlineExceeded`] instead.
#[derive(Debug)]
pub struct BlockWithTimeout(pub Duration);

impl Default for BlockWithTimeout {
    fn default() -> Self {
        BlockWithTimeout(Duration::from_secs(5))
    }
}

impl AdmissionPolicy for BlockWithTimeout {
    fn admit(&self, tx: &Sender<ResizeRequest>, req: ResizeRequest) -> Result<(), SubmitError> {
        let timeout = match req.deadline {
            Some(d) => {
                let budget = d.saturating_duration_since(Instant::now());
                if budget.is_zero() {
                    return Err(SubmitError::DeadlineExceeded);
                }
                self.0.min(budget)
            }
            None => self.0,
        };
        match tx.send_timeout(req, timeout) {
            Ok(()) => Ok(()),
            Err(SendTimeoutError::Timeout(r)) => {
                // Which limit did we hit: the policy's, or the request's?
                if r.is_expired(Instant::now()) {
                    Err(SubmitError::DeadlineExceeded)
                } else {
                    Err(SubmitError::Saturated)
                }
            }
            Err(SendTimeoutError::Disconnected(_)) => Err(SubmitError::ShuttingDown),
        }
    }

    fn name(&self) -> &'static str {
        "block"
    }
}

/// QoS-aware admission: under pressure, `Batch`-class requests are
/// rejected immediately (shed first) while `Interactive` requests may
/// still wait up to the timeout for space.
#[derive(Debug)]
pub struct ShedBatchFirst(pub Duration);

impl Default for ShedBatchFirst {
    fn default() -> Self {
        ShedBatchFirst(Duration::from_secs(5))
    }
}

impl AdmissionPolicy for ShedBatchFirst {
    fn admit(&self, tx: &Sender<ResizeRequest>, req: ResizeRequest) -> Result<(), SubmitError> {
        match req.priority {
            Priority::Batch => RejectWhenFull.admit(tx, req),
            Priority::Interactive => BlockWithTimeout(self.0).admit(tx, req),
        }
    }

    fn name(&self) -> &'static str {
        "shed-batch"
    }
}

/// Resolve an admission policy by CLI/config name. `timeout` feeds the
/// blocking variants.
pub fn admission_by_name(name: &str, timeout: Duration) -> Result<Box<dyn AdmissionPolicy>> {
    match name {
        "reject" | "reject-when-full" => Ok(Box::new(RejectWhenFull)),
        "block" | "block-with-timeout" => Ok(Box::new(BlockWithTimeout(timeout))),
        "shed-batch" | "shed-batch-first" => Ok(Box::new(ShedBatchFirst(timeout))),
        other => bail!(
            "unknown admission policy '{other}' (expected one of: reject, block, shed-batch)"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{Request, RequestKey, Ticket};
    use crate::exec::bounded;
    use crate::image::{generate, Interpolator};
    use std::time::Instant;

    fn req(priority: Priority) -> ResizeRequest {
        let img = generate::gradient(16, 16);
        let (_t, tx) = Ticket::new(0);
        let mut r = ResizeRequest::bare(
            0,
            RequestKey::of(Interpolator::Bilinear, &img, 2),
            img,
            tx,
        );
        r.priority = priority;
        r
    }

    #[test]
    fn reject_when_full_fails_fast() {
        let (tx, _rx) = bounded(1);
        assert!(RejectWhenFull.admit(&tx, req(Priority::Interactive)).is_ok());
        let t0 = Instant::now();
        assert_eq!(
            RejectWhenFull.admit(&tx, req(Priority::Interactive)),
            Err(SubmitError::Saturated)
        );
        assert!(t0.elapsed() < Duration::from_millis(50), "must not block");
    }

    #[test]
    fn block_with_timeout_waits_for_space() {
        let (tx, rx) = bounded(1);
        tx.send(req(Priority::Interactive)).unwrap();
        let policy = BlockWithTimeout(Duration::from_secs(2));
        let drainer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            rx.recv().ok();
            rx // keep the receiver alive until the admit resolves
        });
        let t0 = Instant::now();
        assert!(policy.admit(&tx, req(Priority::Interactive)).is_ok());
        assert!(t0.elapsed() >= Duration::from_millis(20), "waited for space");
        drop(drainer.join().unwrap());
    }

    #[test]
    fn block_with_timeout_saturates_eventually() {
        let (tx, _rx) = bounded(1);
        tx.send(req(Priority::Interactive)).unwrap();
        let policy = BlockWithTimeout(Duration::from_millis(20));
        assert_eq!(
            policy.admit(&tx, req(Priority::Interactive)),
            Err(SubmitError::Saturated)
        );
    }

    #[test]
    fn shed_batch_first_rejects_batch_but_blocks_interactive() {
        let (tx, rx) = bounded(1);
        tx.send(req(Priority::Interactive)).unwrap();
        let policy = ShedBatchFirst(Duration::from_secs(2));
        // batch traffic sheds immediately under pressure
        let t0 = Instant::now();
        assert_eq!(
            policy.admit(&tx, req(Priority::Batch)),
            Err(SubmitError::Saturated)
        );
        assert!(t0.elapsed() < Duration::from_millis(50));
        // interactive traffic waits for the drain
        let drainer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            rx.recv().ok();
            rx
        });
        assert!(policy.admit(&tx, req(Priority::Interactive)).is_ok());
        drop(drainer.join().unwrap());
    }

    #[test]
    fn blocking_wait_is_capped_by_the_request_deadline() {
        let (tx, _rx) = bounded(1);
        tx.send(req(Priority::Interactive)).unwrap();
        // Policy allows 5s, but the request only has ~20ms of budget:
        // admission must give up at the budget, not the policy timeout,
        // and name the deadline as the reason.
        let mut doomed = req(Priority::Interactive);
        doomed.deadline = Some(Instant::now() + Duration::from_millis(20));
        let policy = BlockWithTimeout(Duration::from_secs(5));
        let t0 = Instant::now();
        assert_eq!(
            policy.admit(&tx, doomed),
            Err(SubmitError::DeadlineExceeded)
        );
        assert!(
            t0.elapsed() < Duration::from_millis(500),
            "must not block past the request budget"
        );
        // an already-expired budget fails without waiting at all
        let mut dead = req(Priority::Batch);
        dead.deadline = Some(Instant::now() - Duration::from_millis(1));
        let policy = ShedBatchFirst(Duration::from_secs(5));
        let t0 = Instant::now();
        assert!(policy.admit(&tx, dead).is_err());
        assert!(t0.elapsed() < Duration::from_millis(50));
    }

    #[test]
    fn disconnected_reports_shutdown() {
        let (tx, rx) = bounded(1);
        drop(rx);
        assert_eq!(
            RejectWhenFull.admit(&tx, req(Priority::Interactive)),
            Err(SubmitError::ShuttingDown)
        );
        assert_eq!(
            BlockWithTimeout(Duration::from_millis(5)).admit(&tx, req(Priority::Batch)),
            Err(SubmitError::ShuttingDown)
        );
    }

    #[test]
    fn by_name_resolves_and_rejects() {
        let t = Duration::from_millis(10);
        for (name, want) in [("reject", "reject"), ("block", "block"), ("shed-batch", "shed-batch")]
        {
            assert_eq!(admission_by_name(name, t).unwrap().name(), want);
        }
        let err = admission_by_name("drop-everything", t).unwrap_err().to_string();
        assert!(err.contains("unknown admission policy"), "{err}");
        assert!(err.contains("shed-batch"), "must name alternatives: {err}");
    }

    #[test]
    fn request_builder_feeds_policy_priority() {
        // Request -> ResizeRequest priority propagation is exercised at
        // the service layer; here just pin the builder default.
        let img = generate::gradient(8, 8);
        assert_eq!(
            Request::new(Interpolator::Bilinear, img, 2).priority,
            Priority::Interactive
        );
    }
}
