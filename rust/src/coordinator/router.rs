//! The router: maps a [`RequestKey`] to the artifact that should serve
//! it, preferring the portable tile variant (the paper's §V conclusion,
//! computed by the autotuner) and falling back to whatever variant the
//! manifest offers.

use super::request::RequestKey;
use crate::runtime::{ArtifactEntry, Manifest};
use crate::tiling::TileDim;
use anyhow::{anyhow, Result};
use std::collections::HashMap;

/// Routing table built once from the manifest.
#[derive(Debug, Clone)]
pub struct Router {
    /// Preferred Pallas tile (e.g. the autotuner's portable 32×4).
    pub tile_pref: Option<TileDim>,
    /// Precomputed key → candidate entries (sorted by preference).
    table: HashMap<RequestKey, Vec<ArtifactEntry>>,
}

impl Router {
    /// Build a routing table over `manifest`, preferring `tile_pref`
    /// variants when several serve the same key.
    pub fn new(manifest: &Manifest, tile_pref: Option<TileDim>) -> Router {
        let mut table: HashMap<RequestKey, Vec<ArtifactEntry>> = HashMap::new();
        for e in &manifest.entries {
            let key = RequestKey {
                kernel: e.kernel,
                src: e.src,
                scale: e.scale,
            };
            table.entry(key).or_default().push(e.clone());
        }
        for entries in table.values_mut() {
            entries.sort_by_key(|e| {
                let tile_match = tile_pref.map(|t| e.tile == t).unwrap_or(true);
                // Among equally-preferred variants, larger Pallas tiles
                // first: on the CPU PJRT backend fewer grid steps win
                // (measured 5.7x in `cargo bench --bench artifact_exec`;
                // EXPERIMENTS.md §Perf). A GPU backend would pass an
                // explicit tile_pref from the autotuner instead.
                (!tile_match, e.batch, std::cmp::Reverse(e.tile.threads()))
            });
        }
        Router { tile_pref, table }
    }

    /// Keys this router can serve.
    pub fn keys(&self) -> Vec<RequestKey> {
        let mut ks: Vec<RequestKey> = self.table.keys().copied().collect();
        ks.sort();
        ks
    }

    /// Can this key be served at all?
    pub fn supports(&self, key: &RequestKey) -> bool {
        self.table.contains_key(key)
    }

    /// The artifact for `key` able to carry `batch_size` requests:
    /// smallest sufficient batch among preferred-tile variants, falling
    /// back to the largest available (the batcher will split).
    pub fn route(&self, key: &RequestKey, batch_size: usize) -> Result<&ArtifactEntry> {
        let entries = self
            .table
            .get(key)
            .ok_or_else(|| anyhow!("no artifact serves {key:?}"))?;
        // entries are sorted tile-pref-first then by ascending batch
        entries
            .iter()
            .find(|e| e.batch as usize >= batch_size)
            .or_else(|| entries.iter().max_by_key(|e| e.batch))
            .ok_or_else(|| anyhow!("no artifact serves {key:?}"))
    }

    /// Largest static batch available for `key` (the batcher's cap).
    pub fn max_batch(&self, key: &RequestKey) -> usize {
        self.table
            .get(key)
            .map(|es| es.iter().map(|e| e.batch as usize).max().unwrap_or(1))
            .unwrap_or(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::Interpolator;
    use std::path::PathBuf;

    fn manifest() -> Manifest {
        let text = r#"{
          "version": 1,
          "artifacts": [
            {"name": "bl_s2_b1_t32x4", "kernel": "bilinear", "src": [64, 64],
             "scale": 2, "batch": 1, "tile": [4, 32], "path": "a.hlo.txt"},
            {"name": "bl_s2_b4_t32x4", "kernel": "bilinear", "src": [64, 64],
             "scale": 2, "batch": 4, "tile": [4, 32], "path": "b.hlo.txt"},
            {"name": "bl_s2_b4_t8x8", "kernel": "bilinear", "src": [64, 64],
             "scale": 2, "batch": 4, "tile": [8, 8], "path": "c.hlo.txt"}
          ]
        }"#;
        Manifest::parse(text, PathBuf::from(".")).unwrap()
    }

    fn key() -> RequestKey {
        RequestKey {
            kernel: Interpolator::Bilinear,
            src: (64, 64),
            scale: 2,
        }
    }

    #[test]
    fn routes_by_batch_size() {
        let r = Router::new(&manifest(), Some(TileDim::new(32, 4)));
        assert_eq!(r.route(&key(), 1).unwrap().name, "bl_s2_b1_t32x4");
        assert_eq!(r.route(&key(), 3).unwrap().name, "bl_s2_b4_t32x4");
        assert_eq!(r.route(&key(), 4).unwrap().name, "bl_s2_b4_t32x4");
        // oversize falls back to largest; the batcher splits
        assert_eq!(r.route(&key(), 9).unwrap().batch, 4);
    }

    #[test]
    fn tile_preference_respected() {
        let r = Router::new(&manifest(), Some(TileDim::new(8, 8)));
        assert_eq!(r.route(&key(), 4).unwrap().name, "bl_s2_b4_t8x8");
    }

    #[test]
    fn unknown_key_errors() {
        let r = Router::new(&manifest(), None);
        let bad = RequestKey {
            kernel: Interpolator::Bicubic,
            src: (64, 64),
            scale: 2,
        };
        assert!(r.route(&bad, 1).is_err());
        assert!(!r.supports(&bad));
        assert!(r.supports(&key()));
    }

    #[test]
    fn max_batch() {
        let r = Router::new(&manifest(), None);
        assert_eq!(r.max_batch(&key()), 4);
        assert_eq!(r.keys().len(), 1);
    }
}
