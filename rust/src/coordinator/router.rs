//! The router: maps a [`RequestKey`] to the artifact that should serve
//! it, with the preferred Pallas tile decided by a [`TilePolicy`] — the
//! seam through which autotuner results reach serving.

use super::request::RequestKey;
use crate::autotuner::TuningOutcome;
use crate::runtime::{ArtifactEntry, Manifest};
use crate::tiling::TileDim;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::{Arc, RwLock};

/// A hot-swappable router handle shared between a member's submit path,
/// batcher, and workers.
/// [`FleetController::retune`](super::FleetController::retune) replaces
/// the inner `Arc<Router>` while the pipeline keeps serving;
/// readers snapshot the current router per operation.
pub type SharedRouter = Arc<RwLock<Arc<Router>>>;

/// How the router chooses among tile variants of the same artifact shape.
#[derive(Debug, Clone)]
pub enum TilePolicy {
    /// Always prefer this tile (the old `Some(tile)` behavior).
    Fixed(TileDim),
    /// Prefer each device's tuned best tile from a [`TuningOutcome`];
    /// devices absent from the outcome fall back to its portable pick.
    /// Build one router per serving device with [`Router::for_device`].
    PerDevice(TuningOutcome),
    /// No tuned preference: backend-optimal variant order (largest Pallas
    /// tile first — on the CPU PJRT backend fewer grid steps win,
    /// measured 5.7x in `cargo bench --bench artifact_exec`;
    /// EXPERIMENTS.md §Perf). The old `None` behavior.
    PortableFallback,
}

impl TilePolicy {
    /// The tile this policy prefers when serving `device_id` (`None` =
    /// device unknown / single-backend deployment).
    pub fn tile_for(&self, device_id: Option<&str>) -> Option<TileDim> {
        match self {
            TilePolicy::Fixed(tile) => Some(*tile),
            TilePolicy::PerDevice(outcome) => match device_id {
                Some(id) => outcome.best_for(id).or_else(|| outcome.portable_tile()),
                None => outcome.portable_tile(),
            },
            TilePolicy::PortableFallback => None,
        }
    }
}

/// Routing table built once from the manifest.
#[derive(Debug, Clone)]
pub struct Router {
    /// Resolved preferred Pallas tile (e.g. the autotuner's portable
    /// 32×4, or a device's tuned best under `TilePolicy::PerDevice`).
    pub tile_pref: Option<TileDim>,
    /// The device this router was resolved for (`None` = no identity).
    device_id: Option<String>,
    /// The policy this router was built from.
    policy: TilePolicy,
    /// Precomputed key → candidate entries (sorted by preference).
    table: HashMap<RequestKey, Vec<ArtifactEntry>>,
}

impl Router {
    /// Build a routing table over `manifest` for a deployment with no
    /// specific device identity (see [`Router::for_device`]).
    pub fn new(manifest: &Manifest, policy: TilePolicy) -> Router {
        Self::for_device(manifest, policy, None)
    }

    /// Build a routing table over `manifest` serving `device_id`: the
    /// policy resolves to that device's preferred tile, so each device
    /// routes to its own tuned variant.
    pub fn for_device(manifest: &Manifest, policy: TilePolicy, device_id: Option<&str>) -> Router {
        let tile_pref = policy.tile_for(device_id);
        let mut table: HashMap<RequestKey, Vec<ArtifactEntry>> = HashMap::new();
        for e in &manifest.entries {
            let key = RequestKey {
                kernel: e.kernel,
                src: e.src,
                scale: e.scale,
            };
            table.entry(key).or_default().push(e.clone());
        }
        for entries in table.values_mut() {
            entries.sort_by_key(|e| {
                let tile_match = tile_pref.map(|t| e.tile == t).unwrap_or(true);
                // Among equally-preferred variants, larger Pallas tiles
                // first (the PortableFallback rationale above).
                (!tile_match, e.batch, std::cmp::Reverse(e.tile.threads()))
            });
        }
        Router {
            tile_pref,
            device_id: device_id.map(str::to_string),
            policy,
            table,
        }
    }

    /// Wrap this router in a hot-swappable [`SharedRouter`] handle.
    pub fn into_shared(self) -> SharedRouter {
        Arc::new(RwLock::new(Arc::new(self)))
    }

    /// The policy this router was built from.
    pub fn policy(&self) -> &TilePolicy {
        &self.policy
    }

    /// The device identity this router resolved its tile for.
    pub fn device_id(&self) -> Option<&str> {
        self.device_id.as_deref()
    }

    /// Keys this router can serve.
    pub fn keys(&self) -> Vec<RequestKey> {
        let mut ks: Vec<RequestKey> = self.table.keys().copied().collect();
        ks.sort();
        ks
    }

    /// Can this key be served at all?
    pub fn supports(&self, key: &RequestKey) -> bool {
        self.table.contains_key(key)
    }

    /// The artifact for `key` able to carry `batch_size` requests:
    /// smallest sufficient batch among preferred-tile variants, falling
    /// back to the largest available (the batcher will split).
    pub fn route(&self, key: &RequestKey, batch_size: usize) -> Result<&ArtifactEntry> {
        let entries = self
            .table
            .get(key)
            .ok_or_else(|| anyhow!("no artifact serves {key:?}"))?;
        // entries are sorted tile-pref-first then by ascending batch
        entries
            .iter()
            .find(|e| e.batch as usize >= batch_size)
            .or_else(|| entries.iter().max_by_key(|e| e.batch))
            .ok_or_else(|| anyhow!("no artifact serves {key:?}"))
    }

    /// Largest static batch available for `key` (the batcher's cap).
    pub fn max_batch(&self, key: &RequestKey) -> usize {
        self.table
            .get(key)
            .map(|es| es.iter().map(|e| e.batch as usize).max().unwrap_or(1))
            .unwrap_or(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotuner::{portable_over, DeviceTuning, TunedPoint, TuningOutcome};
    use crate::image::Interpolator;
    use std::path::PathBuf;

    fn manifest() -> Manifest {
        let text = r#"{
          "version": 1,
          "artifacts": [
            {"name": "bl_s2_b1_t32x4", "kernel": "bilinear", "src": [64, 64],
             "scale": 2, "batch": 1, "tile": [4, 32], "path": "a.hlo.txt"},
            {"name": "bl_s2_b4_t32x4", "kernel": "bilinear", "src": [64, 64],
             "scale": 2, "batch": 4, "tile": [4, 32], "path": "b.hlo.txt"},
            {"name": "bl_s2_b4_t8x8", "kernel": "bilinear", "src": [64, 64],
             "scale": 2, "batch": 4, "tile": [8, 8], "path": "c.hlo.txt"}
          ]
        }"#;
        Manifest::parse(text, PathBuf::from(".")).unwrap()
    }

    fn key() -> RequestKey {
        RequestKey {
            kernel: Interpolator::Bilinear,
            src: (64, 64),
            scale: 2,
        }
    }

    /// A hand-built outcome where the two paper devices tune to
    /// different tiles (32x4 vs 8x8).
    fn split_outcome() -> TuningOutcome {
        let gtx = DeviceTuning::from_points(
            "gtx260".to_string(),
            vec![
                TunedPoint {
                    tile: TileDim::new(32, 4),
                    ms: 1.0,
                },
                TunedPoint {
                    tile: TileDim::new(8, 8),
                    ms: 2.0,
                },
            ],
            2,
        )
        .unwrap();
        let gts = DeviceTuning::from_points(
            "8800gts".to_string(),
            vec![
                TunedPoint {
                    tile: TileDim::new(32, 4),
                    ms: 3.0,
                },
                TunedPoint {
                    tile: TileDim::new(8, 8),
                    ms: 1.5,
                },
            ],
            2,
        )
        .unwrap();
        let per_device = vec![gtx, gts];
        TuningOutcome {
            kernel: Interpolator::Bilinear,
            scale: 2,
            src: (64, 64),
            strategy: "exhaustive".to_string(),
            evaluations: 4,
            per_device: per_device.clone(),
            portable: portable_over(&per_device),
        }
    }

    #[test]
    fn routes_by_batch_size() {
        let r = Router::new(&manifest(), TilePolicy::Fixed(TileDim::new(32, 4)));
        assert_eq!(r.route(&key(), 1).unwrap().name, "bl_s2_b1_t32x4");
        assert_eq!(r.route(&key(), 3).unwrap().name, "bl_s2_b4_t32x4");
        assert_eq!(r.route(&key(), 4).unwrap().name, "bl_s2_b4_t32x4");
        // oversize falls back to largest; the batcher splits
        assert_eq!(r.route(&key(), 9).unwrap().batch, 4);
    }

    #[test]
    fn tile_preference_respected() {
        let r = Router::new(&manifest(), TilePolicy::Fixed(TileDim::new(8, 8)));
        assert_eq!(r.route(&key(), 4).unwrap().name, "bl_s2_b4_t8x8");
    }

    #[test]
    fn per_device_policy_routes_each_device_to_its_tuned_tile() {
        let outcome = split_outcome();
        let policy = TilePolicy::PerDevice(outcome.clone());
        let ra = Router::for_device(&manifest(), policy.clone(), Some("gtx260"));
        assert_eq!(ra.tile_pref, Some(TileDim::new(32, 4)));
        assert_eq!(ra.device_id(), Some("gtx260"));
        assert_eq!(ra.route(&key(), 4).unwrap().name, "bl_s2_b4_t32x4");
        let rb = Router::for_device(&manifest(), policy.clone(), Some("8800gts"));
        assert_eq!(rb.tile_pref, Some(TileDim::new(8, 8)));
        assert_eq!(rb.route(&key(), 4).unwrap().name, "bl_s2_b4_t8x8");
        // an untuned device falls back to the outcome's portable pick
        let rc = Router::for_device(&manifest(), policy, Some("fermi480"));
        assert_eq!(rc.tile_pref, outcome.portable_tile());
    }

    #[test]
    fn portable_fallback_prefers_largest_tile() {
        let r = Router::new(&manifest(), TilePolicy::PortableFallback);
        assert_eq!(r.tile_pref, None);
        // 32x4 (128 threads) outranks 8x8 (64 threads) at equal batch
        assert_eq!(r.route(&key(), 4).unwrap().name, "bl_s2_b4_t32x4");
        assert!(matches!(r.policy(), TilePolicy::PortableFallback));
    }

    #[test]
    fn unknown_key_errors() {
        let r = Router::new(&manifest(), TilePolicy::PortableFallback);
        let bad = RequestKey {
            kernel: Interpolator::Bicubic,
            src: (64, 64),
            scale: 2,
        };
        assert!(r.route(&bad, 1).is_err());
        assert!(!r.supports(&bad));
        assert!(r.supports(&key()));
    }

    #[test]
    fn max_batch() {
        let r = Router::new(&manifest(), TilePolicy::PortableFallback);
        assert_eq!(r.max_batch(&key()), 4);
        assert_eq!(r.keys().len(), 1);
    }
}
