//! The background retune daemon: watches a [`TuningDb`] file and drives
//! the fleet's control plane when it changes.
//!
//! The paper's finding — a tile tuned for one GPU model degrades on
//! another "especially when some external conditions were changed" —
//! means tuning is an ongoing process, not a build-time decision. The
//! operational loop this module closes:
//!
//! 1. a re-tuning run (e.g. `tilekit tune --cache tuning_cache.json`)
//!    refreshes the persistent tuning database;
//! 2. the daemon notices the file changed (content fingerprint, not just
//!    mtime — coarse filesystem timestamps must not hide a rewrite);
//! 3. it assembles a fresh fleet outcome with [`TuningDb::outcome_for`]
//!    and issues [`FleetController::retune`] for every member whose
//!    winner actually moved — a hot swap, no fleet drain.
//!
//! Exposed on the CLI as `tilekit serve --watch-db <path>`.

use super::server::FleetController;
use crate::autotuner::TuningDb;
use crate::image::Interpolator;
use crate::metrics::Counter;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Which tuning-database key the daemon watches: the serving shape plus
/// the two facts that make cache entries non-interchangeable (strategy
/// and candidate tile set — see [`TuningDb::key`]).
#[derive(Debug, Clone)]
pub struct RetuneSpec {
    pub kernel: Interpolator,
    pub scale: u32,
    /// Source size, in the same orientation the tuning runs were keyed
    /// with (a `TuningSession`'s `src`).
    pub src: (u32, u32),
    /// Strategy name the cache entries were produced by.
    pub strategy: String,
    /// Candidate-tile-set fingerprint ([`TuningDb::tiles_fingerprint`]).
    pub tiles_fp: String,
}

/// Live counters of one daemon's activity.
#[derive(Debug, Default)]
pub struct RetuneDaemonStats {
    /// Poll ticks that looked at the file.
    pub polls: Counter,
    /// Distinct file contents observed (including the first sighting).
    pub refreshes: Counter,
    /// `retune` commands issued (members whose winner moved).
    pub applied: Counter,
    /// Refreshes that could not be applied (unreadable/incomplete db).
    pub errors: Counter,
}

/// A cheap content fingerprint ([`crate::util::fnv1a64`]): refresh
/// detection must survive filesystems with coarse mtime granularity and
/// same-length rewrites.
fn fingerprint(bytes: &[u8]) -> u64 {
    crate::util::fnv1a64(bytes.iter().copied())
}

/// One refresh: reload `db`, assemble the fleet outcome for the watched
/// key, and retune every member whose current preferred tile differs
/// from the refreshed winner. Returns how many members were retuned.
/// Errors when the db has no complete outcome for the fleet's devices
/// (a partial outcome would silently hide staleness).
pub fn apply_refresh(
    controller: &FleetController,
    db: &TuningDb,
    spec: &RetuneSpec,
) -> anyhow::Result<usize> {
    let topo = controller.topology();
    let labels: Vec<Arc<str>> = {
        let mut seen: Vec<Arc<str>> = Vec::new();
        for m in topo.members.iter().filter(|m| m.device.is_some()) {
            if !seen.contains(&m.label) {
                seen.push(Arc::clone(&m.label));
            }
        }
        seen
    };
    if labels.is_empty() {
        anyhow::bail!("fleet has no device members to retune");
    }
    let ids: Vec<&str> = labels.iter().map(|l| &**l).collect();
    let outcome = db
        .outcome_for(
            spec.kernel,
            spec.scale,
            spec.src,
            &spec.strategy,
            &spec.tiles_fp,
            &ids,
        )
        .ok_or_else(|| {
            anyhow::anyhow!(
                "tuning db has no complete outcome for devices {ids:?} at the watched key"
            )
        })?;
    let mut applied = 0;
    for label in &labels {
        let fresh = outcome.best_for(label).or_else(|| outcome.portable_tile());
        // Labels are not unique (a fleet may run several identical
        // GPUs): retune when ANY member under this label is off the
        // fresh winner — retune itself rebuilds every one of them.
        let stale = topo
            .members
            .iter()
            .filter(|m| m.label == *label)
            .any(|m| m.tile_pref != fresh);
        if stale {
            controller.retune(label, &outcome)?;
            applied += 1;
        }
    }
    Ok(applied)
}

/// The background watcher. Spawn with [`RetuneDaemon::spawn`]; the
/// thread exits on [`stop`](RetuneDaemon::stop), when dropped, or when
/// the watched fleet shuts down.
pub struct RetuneDaemon {
    stop: Arc<AtomicBool>,
    stats: Arc<RetuneDaemonStats>,
    handle: Option<JoinHandle<()>>,
}

impl RetuneDaemon {
    /// Start watching `path` every `poll`, driving `controller` on
    /// change. A missing file is not an error — the daemon waits for it
    /// to appear (the first successful read counts as a refresh).
    pub fn spawn(
        controller: FleetController,
        path: PathBuf,
        spec: RetuneSpec,
        poll: Duration,
    ) -> RetuneDaemon {
        let stop = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(RetuneDaemonStats::default());
        let handle = {
            let stop = Arc::clone(&stop);
            let stats = Arc::clone(&stats);
            std::thread::Builder::new()
                .name("tilekit-retune-daemon".into())
                .spawn(move || run_daemon(controller, &path, &spec, poll, &stop, &stats))
                .expect("spawn retune daemon")
        };
        RetuneDaemon {
            stop,
            stats,
            handle: Some(handle),
        }
    }

    /// The daemon's live activity counters.
    pub fn stats(&self) -> &Arc<RetuneDaemonStats> {
        &self.stats
    }

    /// Stop the watcher and join its thread.
    pub fn stop(mut self) {
        self.stop_inner();
    }

    fn stop_inner(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for RetuneDaemon {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

fn run_daemon(
    controller: FleetController,
    path: &Path,
    spec: &RetuneSpec,
    poll: Duration,
    stop: &AtomicBool,
    stats: &RetuneDaemonStats,
) {
    // Sleep in short slices so stop() returns promptly even with a
    // long poll interval.
    let slice = poll.min(Duration::from_millis(50)).max(Duration::from_millis(1));
    // `applied_state`: the (content fingerprint, topology epoch) pair
    // the db was last successfully applied against. Re-applying when
    // the EPOCH moved (not just the file) reconciles members added
    // after the last refresh, whose build-time policy may disagree with
    // the db. `seen_fp` tracks the last content attempted, so each
    // distinct file state is counted once in `refreshes`/`errors`; a
    // refresh whose apply failed transiently (e.g. the fleet briefly
    // held a member the db has no entry for) keeps retrying every poll
    // until it applies or the file changes again.
    let mut applied_state: Option<(u64, u64)> = None;
    let mut seen_fp: Option<u64> = None;
    let mut since_poll = poll; // poll immediately on startup
    while !stop.load(Ordering::Acquire) && !controller.is_closed() {
        if since_poll < poll {
            std::thread::sleep(slice);
            since_poll += slice;
            continue;
        }
        since_poll = Duration::ZERO;
        stats.polls.inc();
        let Ok(bytes) = std::fs::read(path) else {
            continue; // missing/unreadable: keep waiting
        };
        let fp = fingerprint(&bytes);
        // The epoch is read BEFORE applying: a membership change racing
        // the apply leaves `applied_state` stale, so the next poll
        // re-applies and converges.
        let epoch = controller.epoch();
        if applied_state == Some((fp, epoch)) {
            continue;
        }
        let fresh_content = seen_fp != Some(fp);
        if fresh_content {
            seen_fp = Some(fp);
            stats.refreshes.inc();
        }
        // Parse the bytes already read for change detection — one read
        // per poll, and the applied content is exactly the content the
        // fingerprint describes (no read-read race).
        match TuningDb::from_json_str(&String::from_utf8_lossy(&bytes))
            .and_then(|db| apply_refresh(&controller, &db, spec))
        {
            Ok(applied) => {
                stats.applied.add(applied as u64);
                applied_state = Some((fp, epoch));
            }
            Err(_) => {
                if fresh_content {
                    stats.errors.inc();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotuner::{DeviceTuning, TunedPoint};
    use crate::config::ServingConfig;
    use crate::coordinator::{FleetBuilder, TilePolicy};
    use crate::runtime::{Manifest, MockEngine};
    use crate::tiling::TileDim;

    fn tuning(id: &str, best: TileDim, other: TileDim) -> DeviceTuning {
        DeviceTuning::from_points(
            id.to_string(),
            vec![
                TunedPoint { tile: best, ms: 1.0 },
                TunedPoint { tile: other, ms: 2.0 },
            ],
            2,
        )
        .unwrap()
    }

    fn spec(fp: &str) -> RetuneSpec {
        RetuneSpec {
            kernel: Interpolator::Bilinear,
            scale: 2,
            src: (64, 64),
            strategy: "exhaustive".to_string(),
            tiles_fp: fp.to_string(),
        }
    }

    #[test]
    fn apply_refresh_retunes_only_moved_winners() {
        let t16x8 = TileDim::new(16, 8);
        let t32x16 = TileDim::new(32, 16);
        let fp = TuningDb::tiles_fingerprint(&[t16x8, t32x16]);
        let mut db = TuningDb::in_memory();
        db.insert(
            Interpolator::Bilinear,
            2,
            (64, 64),
            "exhaustive",
            &fp,
            tuning("gtx260", t16x8, t32x16),
        );
        db.insert(
            Interpolator::Bilinear,
            2,
            (64, 64),
            "exhaustive",
            &fp,
            tuning("fermi", t16x8, t32x16),
        );
        let stale = db
            .outcome_for(
                Interpolator::Bilinear,
                2,
                (64, 64),
                "exhaustive",
                &fp,
                &["gtx260", "fermi"],
            )
            .unwrap();
        let cfg = ServingConfig {
            workers: 1,
            batch_max: Some(4),
            ..ServingConfig::default()
        };
        let fleet = FleetBuilder::new(&cfg, &Manifest::fleet_demo())
            .device(
                crate::device::find_device("gtx260").unwrap(),
                Arc::new(MockEngine::new()),
                TilePolicy::PerDevice(stale.clone()),
            )
            .device(
                crate::device::find_device("fermi").unwrap(),
                Arc::new(MockEngine::new()),
                TilePolicy::PerDevice(stale),
            )
            .build()
            .unwrap();
        let ctl = fleet.controller();
        // Same winners -> nothing to apply.
        assert_eq!(apply_refresh(&ctl, &db, &spec(&fp)).unwrap(), 0);
        // Flip fermi's winner -> exactly one member retunes.
        db.insert(
            Interpolator::Bilinear,
            2,
            (64, 64),
            "exhaustive",
            &fp,
            tuning("fermi", t32x16, t16x8),
        );
        assert_eq!(apply_refresh(&ctl, &db, &spec(&fp)).unwrap(), 1);
        let views = fleet.members();
        let tile_of = |label: &str| {
            views
                .iter()
                .find(|v| &*v.label == label)
                .and_then(|v| v.tile_pref)
        };
        assert_eq!(tile_of("gtx260"), Some(t16x8));
        assert_eq!(tile_of("fermi"), Some(t32x16));
        // An incomplete db (wrong key) errors instead of half-applying.
        assert!(apply_refresh(&ctl, &db, &spec("deadbeef")).is_err());
        let stats = fleet.shutdown();
        assert_eq!(stats.retunes.get(), 1);
    }

    #[test]
    fn fingerprint_distinguishes_contents() {
        assert_ne!(fingerprint(b"abc"), fingerprint(b"abd"));
        assert_eq!(fingerprint(b"abc"), fingerprint(b"abc"));
    }
}
