//! Request/response types for the resize service: the typed [`Request`]
//! builder callers submit, the internal [`ResizeRequest`] that rides the
//! pipeline, and the caller's [`Ticket`] handle (waitable, pollable,
//! cancellable).

use crate::image::{Image, Interpolator};
use anyhow::Result;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// QoS class of a request. `Interactive` requests are the latency-
/// sensitive traffic; `Batch` requests are throughput work the admission
/// layer may shed first under pressure (see
/// [`ShedBatchFirst`](super::admission::ShedBatchFirst)).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Priority {
    /// Latency-sensitive; admitted preferentially.
    Interactive,
    /// Throughput work; first to be shed under overload.
    Batch,
}

impl Priority {
    /// Both classes, in index order.
    pub const ALL: [Priority; 2] = [Priority::Interactive, Priority::Batch];

    /// Dense index used by per-class stats arrays.
    pub fn index(self) -> usize {
        match self {
            Priority::Interactive => 0,
            Priority::Batch => 1,
        }
    }

    /// Label used in reports.
    pub fn label(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
        }
    }
}

/// The batching key: requests sharing it can ride the same artifact.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RequestKey {
    pub kernel: Interpolator,
    /// Source size (h, w).
    pub src: (u32, u32),
    pub scale: u32,
}

impl RequestKey {
    pub fn of(kernel: Interpolator, img: &Image<f32>, scale: u32) -> RequestKey {
        RequestKey {
            kernel,
            src: (img.height() as u32, img.width() as u32),
            scale,
        }
    }
}

/// A typed resize request: what to do, how urgent it is, and how long it
/// is worth doing. Build one with [`Request::new`] and submit it through
/// [`Fleet::submit`](super::Fleet::submit).
///
/// ```no_run
/// # use tilekit::coordinator::{Priority, Request};
/// # use tilekit::image::{generate, Interpolator};
/// let req = Request::new(Interpolator::Bilinear, generate::gradient(64, 64), 2)
///     .priority(Priority::Batch)
///     .deadline(std::time::Duration::from_millis(50));
/// ```
pub struct Request {
    pub kernel: Interpolator,
    pub image: Image<f32>,
    pub scale: u32,
    pub priority: Priority,
    /// Latency budget from submission; `None` = no deadline. A request
    /// whose budget expires before a worker picks it up is shed with a
    /// deadline error instead of occupying an executor.
    pub deadline: Option<Duration>,
}

impl Request {
    /// A request with default QoS (`Interactive`, no deadline).
    pub fn new(kernel: Interpolator, image: Image<f32>, scale: u32) -> Request {
        Request {
            kernel,
            image,
            scale,
            priority: Priority::Interactive,
            deadline: None,
        }
    }

    /// Set the QoS class.
    pub fn priority(mut self, p: Priority) -> Request {
        self.priority = p;
        self
    }

    /// Set the latency budget. `Duration::ZERO` fails fast at submit
    /// with [`SubmitError::DeadlineExceeded`](super::SubmitError).
    pub fn deadline(mut self, budget: Duration) -> Request {
        self.deadline = Some(budget);
        self
    }

    /// The batching/routing key of this request.
    pub fn key(&self) -> RequestKey {
        RequestKey::of(self.kernel, &self.image, self.scale)
    }
}

/// Shared cancellation flag between a [`Ticket`] and its in-flight
/// [`ResizeRequest`]. Cancellation is cooperative: the batcher and the
/// worker check it before (not during) execution.
#[derive(Debug, Clone, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn cancel(&self) {
        self.0.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Acquire)
    }
}

/// An in-flight resize request (pipeline-internal).
pub struct ResizeRequest {
    pub id: u64,
    pub key: RequestKey,
    pub image: Image<f32>,
    pub priority: Priority,
    /// Absolute expiry instant, if the caller set a budget.
    pub deadline: Option<Instant>,
    /// Cooperative cancellation flag shared with the caller's ticket.
    pub cancel: CancelToken,
    /// Admission timestamp (queue latency accounting).
    pub admitted: Instant,
    /// Reply channel.
    pub reply: mpsc::Sender<Result<Image<f32>>>,
}

impl ResizeRequest {
    /// Build a bare request for direct pipeline driving (tests, benches):
    /// interactive, no deadline, fresh cancel token.
    pub fn bare(
        id: u64,
        key: RequestKey,
        image: Image<f32>,
        reply: mpsc::Sender<Result<Image<f32>>>,
    ) -> ResizeRequest {
        ResizeRequest {
            id,
            key,
            image,
            priority: Priority::Interactive,
            deadline: None,
            cancel: CancelToken::default(),
            admitted: Instant::now(),
            reply,
        }
    }

    /// Has this request been cancelled by its ticket?
    pub fn is_cancelled(&self) -> bool {
        self.cancel.is_cancelled()
    }

    /// Has the latency budget expired as of `now`?
    pub fn is_expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// The caller's handle to a pending request.
#[derive(Debug)]
pub struct Ticket {
    pub id: u64,
    rx: mpsc::Receiver<Result<Image<f32>>>,
    cancel: CancelToken,
    /// Shared with the service's member label — no per-submit String
    /// allocation on the hot path.
    device: Option<Arc<str>>,
}

impl Ticket {
    /// Create a ticket + its reply sender. Public so external harnesses
    /// (benches, property tests) can drive `worker::run_batch` directly.
    pub fn new(id: u64) -> (Ticket, mpsc::Sender<Result<Image<f32>>>) {
        Self::for_device(id, CancelToken::default(), None)
    }

    /// Create a ticket bound to a cancel token and (optionally) the
    /// serving device the scheduler picked.
    pub fn for_device(
        id: u64,
        cancel: CancelToken,
        device: Option<Arc<str>>,
    ) -> (Ticket, mpsc::Sender<Result<Image<f32>>>) {
        let (tx, rx) = mpsc::channel();
        (
            Ticket {
                id,
                rx,
                cancel,
                device,
            },
            tx,
        )
    }

    /// The device this request was scheduled onto (`None` for tickets
    /// built outside a [`Fleet`](super::Fleet)).
    pub fn device_id(&self) -> Option<&str> {
        self.device.as_deref()
    }

    /// The cancellation token this ticket controls (the service clones
    /// it into the in-flight request).
    pub(crate) fn cancel_token(&self) -> CancelToken {
        self.cancel.clone()
    }

    /// Request cancellation. Cooperative: a request already executing
    /// runs to completion; one still queued is shed before it reaches a
    /// worker and its `wait` returns a cancellation error.
    pub fn cancel(&self) {
        self.cancel.cancel();
    }

    /// Block until the response arrives.
    pub fn wait(self) -> Result<Image<f32>> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(anyhow::anyhow!(
                "request {} dropped: service shut down",
                self.id
            )),
        }
    }

    /// Non-blocking poll; `Ok(None)` while still pending.
    pub fn try_wait(&self) -> Result<Option<Image<f32>>> {
        self.wait_timeout(Duration::ZERO)
    }

    /// Wait with a timeout; `Ok(None)` on timeout.
    pub fn wait_timeout(&self, d: Duration) -> Result<Option<Image<f32>>> {
        match self.rx.recv_timeout(d) {
            Ok(Ok(img)) => Ok(Some(img)),
            Ok(Err(e)) => Err(e),
            Err(mpsc::RecvTimeoutError::Timeout) => Ok(None),
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(anyhow::anyhow!(
                "request {} dropped: service shut down",
                self.id
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::generate;

    #[test]
    fn key_of_image() {
        let img = generate::gradient(64, 32);
        let k = RequestKey::of(Interpolator::Bilinear, &img, 2);
        assert_eq!(k.src, (32, 64));
        assert_eq!(k.scale, 2);
    }

    #[test]
    fn request_builder_defaults_and_overrides() {
        let img = generate::gradient(16, 16);
        let r = Request::new(Interpolator::Bilinear, img.clone(), 2);
        assert_eq!(r.priority, Priority::Interactive);
        assert!(r.deadline.is_none());
        assert_eq!(r.key(), RequestKey::of(Interpolator::Bilinear, &img, 2));
        let r = r
            .priority(Priority::Batch)
            .deadline(Duration::from_millis(5));
        assert_eq!(r.priority, Priority::Batch);
        assert_eq!(r.deadline, Some(Duration::from_millis(5)));
    }

    #[test]
    fn priority_indices_dense() {
        assert_eq!(Priority::ALL.len(), 2);
        for (i, p) in Priority::ALL.iter().enumerate() {
            assert_eq!(p.index(), i);
        }
        assert_eq!(Priority::Interactive.label(), "interactive");
        assert_eq!(Priority::Batch.label(), "batch");
    }

    #[test]
    fn ticket_round_trip() {
        let (ticket, tx) = Ticket::new(7);
        tx.send(Ok(generate::gradient(4, 4))).unwrap();
        let img = ticket.wait().unwrap();
        assert_eq!(img.width(), 4);
    }

    #[test]
    fn ticket_reports_shutdown() {
        let (ticket, tx) = Ticket::new(9);
        drop(tx);
        let err = ticket.wait().unwrap_err();
        assert!(err.to_string().contains("shut down"));
    }

    #[test]
    fn ticket_timeout_and_try_wait() {
        let (ticket, tx) = Ticket::new(1);
        let r = ticket
            .wait_timeout(std::time::Duration::from_millis(10))
            .unwrap();
        assert!(r.is_none());
        assert!(ticket.try_wait().unwrap().is_none());
        tx.send(Ok(generate::gradient(4, 4))).unwrap();
        assert!(ticket.try_wait().unwrap().is_some());
    }

    #[test]
    fn cancel_token_reaches_request() {
        let token = CancelToken::default();
        let (ticket, tx) = Ticket::for_device(3, token.clone(), Some("gtx260".into()));
        assert_eq!(ticket.device_id(), Some("gtx260"));
        let img = generate::gradient(8, 8);
        let req = ResizeRequest {
            id: 3,
            key: RequestKey::of(Interpolator::Bilinear, &img, 2),
            image: img,
            priority: Priority::Interactive,
            deadline: None,
            cancel: token,
            admitted: Instant::now(),
            reply: tx,
        };
        assert!(!req.is_cancelled());
        ticket.cancel();
        assert!(req.is_cancelled());
    }

    #[test]
    fn expiry_is_deadline_relative() {
        let img = generate::gradient(8, 8);
        let (_t, tx) = Ticket::new(0);
        let mut req = ResizeRequest::bare(
            0,
            RequestKey::of(Interpolator::Bilinear, &img, 2),
            img,
            tx,
        );
        let now = Instant::now();
        assert!(!req.is_expired(now), "no deadline never expires");
        req.deadline = Some(now + Duration::from_millis(10));
        assert!(!req.is_expired(now));
        assert!(req.is_expired(now + Duration::from_millis(11)));
    }
}
