//! Request/response types for the resize service.

use crate::image::{Image, Interpolator};
use anyhow::Result;
use std::sync::mpsc;
use std::time::Instant;

/// The batching key: requests sharing it can ride the same artifact.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RequestKey {
    pub kernel: Interpolator,
    /// Source size (h, w).
    pub src: (u32, u32),
    pub scale: u32,
}

impl RequestKey {
    pub fn of(kernel: Interpolator, img: &Image<f32>, scale: u32) -> RequestKey {
        RequestKey {
            kernel,
            src: (img.height() as u32, img.width() as u32),
            scale,
        }
    }
}

/// An in-flight resize request.
pub struct ResizeRequest {
    pub id: u64,
    pub key: RequestKey,
    pub image: Image<f32>,
    /// Admission timestamp (queue latency accounting).
    pub admitted: Instant,
    /// Reply channel.
    pub reply: mpsc::Sender<Result<Image<f32>>>,
}

/// The caller's handle to a pending request.
#[derive(Debug)]
pub struct Ticket {
    pub id: u64,
    rx: mpsc::Receiver<Result<Image<f32>>>,
}

impl Ticket {
    /// Create a ticket + its reply sender. Public so external harnesses
    /// (benches, property tests) can drive `worker::run_batch` directly.
    pub fn new(id: u64) -> (Ticket, mpsc::Sender<Result<Image<f32>>>) {
        let (tx, rx) = mpsc::channel();
        (Ticket { id, rx }, tx)
    }

    /// Block until the response arrives.
    pub fn wait(self) -> Result<Image<f32>> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(anyhow::anyhow!(
                "request {} dropped: coordinator shut down",
                self.id
            )),
        }
    }

    /// Wait with a timeout; `Ok(None)` on timeout.
    pub fn wait_timeout(&self, d: std::time::Duration) -> Result<Option<Image<f32>>> {
        match self.rx.recv_timeout(d) {
            Ok(Ok(img)) => Ok(Some(img)),
            Ok(Err(e)) => Err(e),
            Err(mpsc::RecvTimeoutError::Timeout) => Ok(None),
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(anyhow::anyhow!(
                "request {} dropped: coordinator shut down",
                self.id
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::generate;

    #[test]
    fn key_of_image() {
        let img = generate::gradient(64, 32);
        let k = RequestKey::of(Interpolator::Bilinear, &img, 2);
        assert_eq!(k.src, (32, 64));
        assert_eq!(k.scale, 2);
    }

    #[test]
    fn ticket_round_trip() {
        let (ticket, tx) = Ticket::new(7);
        tx.send(Ok(generate::gradient(4, 4))).unwrap();
        let img = ticket.wait().unwrap();
        assert_eq!(img.width(), 4);
    }

    #[test]
    fn ticket_reports_shutdown() {
        let (ticket, tx) = Ticket::new(9);
        drop(tx);
        let err = ticket.wait().unwrap_err();
        assert!(err.to_string().contains("shut down"));
    }

    #[test]
    fn ticket_timeout() {
        let (ticket, _tx) = Ticket::new(1);
        let r = ticket
            .wait_timeout(std::time::Duration::from_millis(10))
            .unwrap();
        assert!(r.is_none());
    }
}
