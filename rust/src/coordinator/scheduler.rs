//! Device scheduling: which fleet member serves the next request.
//!
//! The [`Service`](super::Service) snapshots every member's state into a
//! [`DeviceSnapshot`] slice and asks the configured [`Scheduler`] to pick
//! one. Members that cannot route the request's key (`supports == false`)
//! must never be picked — every implementation filters on it, and the
//! service double-checks before admitting.
//!
//! Three built-ins cover the obvious operating points:
//!
//! * [`RoundRobin`] — fair rotation; the baseline.
//! * [`LeastLoaded`] — pick the member with the fewest unanswered
//!   requests (queue + in-flight).
//! * [`CostModelEta`] — pick the member with the smallest estimated
//!   completion time `(load + 1) × cost_ms`, where `cost_ms` is the
//!   [`CostModel`](crate::autotuner::CostModel) (by default the timing
//!   simulator) estimate of serving this key on that device *through the
//!   tile its router prefers* — so a device whose tuned tile is fast for
//!   this shape attracts proportionally more traffic.

use super::request::RequestKey;
use crate::autotuner::CostModel;
use crate::device::DeviceDescriptor;
use crate::runtime::ArtifactEntry;
use crate::sim::Launch;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// One fleet member's state at scheduling time.
#[derive(Debug, Clone)]
pub struct DeviceSnapshot<'a> {
    /// Index into the service's member list.
    pub index: usize,
    /// Device id (or a synthetic label for anonymous members).
    pub device_id: &'a str,
    /// Can this member's router serve the request key?
    pub supports: bool,
    /// Requests admitted to this member and not yet answered — this
    /// already includes everything still sitting in its admission
    /// queue, so it IS the member's total backlog.
    pub inflight: u64,
    /// Cost-model estimate (ms) of one request of this key on this
    /// member's preferred tile variant; `None` when no estimate exists.
    pub cost_ms: Option<f64>,
}

impl DeviceSnapshot<'_> {
    /// Total unanswered load on this member.
    pub fn load(&self) -> u64 {
        self.inflight
    }
}

/// Picks the serving device for one request.
pub trait Scheduler: Send + Sync {
    /// Return the `index` of a member with `supports == true`, or `None`
    /// when no member can serve the key.
    fn pick(&self, key: &RequestKey, fleet: &[DeviceSnapshot]) -> Option<usize>;

    /// Label for reports and `tilekit serve` output.
    fn name(&self) -> &'static str;
}

/// Fair rotation over supporting members.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: AtomicUsize,
}

impl Scheduler for RoundRobin {
    fn pick(&self, _key: &RequestKey, fleet: &[DeviceSnapshot]) -> Option<usize> {
        if fleet.is_empty() {
            return None;
        }
        let start = self.next.fetch_add(1, Ordering::Relaxed);
        (0..fleet.len())
            .map(|i| &fleet[(start + i) % fleet.len()])
            .find(|s| s.supports)
            .map(|s| s.index)
    }

    fn name(&self) -> &'static str {
        "round-robin"
    }
}

/// Pick the supporting member with the least unanswered load (ties break
/// toward the lower index, keeping the choice deterministic).
#[derive(Debug, Default)]
pub struct LeastLoaded;

impl Scheduler for LeastLoaded {
    fn pick(&self, _key: &RequestKey, fleet: &[DeviceSnapshot]) -> Option<usize> {
        fleet
            .iter()
            .filter(|s| s.supports)
            .min_by_key(|s| (s.load(), s.index))
            .map(|s| s.index)
    }

    fn name(&self) -> &'static str {
        "least-loaded"
    }
}

/// Pick the member with the smallest estimated completion time
/// `(load + 1) × cost_ms`. Members without a cost estimate rank last
/// (but are still eligible — a fleet mixing simulated and opaque
/// backends degrades to least-loaded among the opaque ones).
#[derive(Debug, Default)]
pub struct CostModelEta;

impl Scheduler for CostModelEta {
    fn pick(&self, _key: &RequestKey, fleet: &[DeviceSnapshot]) -> Option<usize> {
        fleet
            .iter()
            .filter(|s| s.supports)
            .min_by(|a, b| {
                let eta = |s: &DeviceSnapshot| {
                    s.cost_ms
                        .map(|c| (s.load() as f64 + 1.0) * c)
                        .unwrap_or(f64::INFINITY)
                };
                eta(a)
                    .total_cmp(&eta(b))
                    .then_with(|| a.load().cmp(&b.load()))
                    .then_with(|| a.index.cmp(&b.index))
            })
            .map(|s| s.index)
    }

    fn name(&self) -> &'static str {
        "cost-eta"
    }
}

/// Resolve a scheduler by CLI/config name.
pub fn scheduler_by_name(name: &str) -> Result<Box<dyn Scheduler>> {
    match name {
        "round-robin" | "rr" => Ok(Box::new(RoundRobin::default())),
        "least-loaded" | "ll" => Ok(Box::new(LeastLoaded)),
        "cost-eta" | "eta" => Ok(Box::new(CostModelEta)),
        other => bail!(
            "unknown scheduler '{other}' (expected one of: round-robin, least-loaded, cost-eta)"
        ),
    }
}

/// Per-device cost oracle: estimates (via a [`CostModel`], by default the
/// timing simulator) how long one request takes through a given artifact
/// variant on this device. The service uses it to build the
/// [`CostModelEta`] estimate table; workers use it to meter the
/// aggregate sim cost a simulated fleet accumulates.
pub struct CostMeter {
    device: DeviceDescriptor,
    model: Arc<dyn CostModel + Send + Sync>,
}

impl CostMeter {
    pub fn new(device: DeviceDescriptor, model: Arc<dyn CostModel + Send + Sync>) -> CostMeter {
        CostMeter { device, model }
    }

    /// The device this meter prices for.
    pub fn device(&self) -> &DeviceDescriptor {
        &self.device
    }

    /// Estimated time (ms) of ONE request through `entry` on this
    /// device: the sim cost of the entry's tile at the entry's shape.
    pub fn ms_of(&self, entry: &ArtifactEntry) -> f64 {
        let launch = Launch {
            kernel: entry.kernel,
            tile: entry.tile,
            // ArtifactEntry.src is (h, w); Launch wants w/h.
            src_w: entry.src.1,
            src_h: entry.src.0,
            scale: entry.scale,
        };
        self.model.evaluate(&launch, &self.device).ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotuner::SimCostModel;
    use crate::device::find_device;
    use crate::image::Interpolator;
    use crate::tiling::TileDim;

    fn key() -> RequestKey {
        RequestKey {
            kernel: Interpolator::Bilinear,
            src: (64, 64),
            scale: 2,
        }
    }

    fn snap(index: usize, supports: bool, inflight: u64, cost_ms: Option<f64>) -> DeviceSnapshot<'static> {
        DeviceSnapshot {
            index,
            device_id: "d",
            supports,
            inflight,
            cost_ms,
        }
    }

    #[test]
    fn round_robin_rotates_over_supporting() {
        let rr = RoundRobin::default();
        let fleet = [snap(0, true, 0, None), snap(1, false, 0, None), snap(2, true, 0, None)];
        let picks: Vec<usize> = (0..4).map(|_| rr.pick(&key(), &fleet).unwrap()).collect();
        // starts rotate 0,1,2,3; member 1 never serves, the scan lands on
        // the next supporting member each time
        assert_eq!(picks, vec![0, 2, 2, 0], "skips the unsupporting member");
        assert!(picks.iter().all(|&i| i != 1));
        assert!(rr.pick(&key(), &[snap(0, false, 0, None)]).is_none());
        assert!(rr.pick(&key(), &[]).is_none());
    }

    #[test]
    fn least_loaded_prefers_idle() {
        let ll = LeastLoaded;
        let fleet = [snap(0, true, 5, None), snap(1, true, 2, None), snap(2, false, 0, None)];
        assert_eq!(ll.pick(&key(), &fleet), Some(1));
        // ties break toward the lower index, deterministically
        let fleet = [snap(0, true, 3, None), snap(1, true, 3, None)];
        assert_eq!(ll.pick(&key(), &fleet), Some(0));
    }

    #[test]
    fn cost_eta_weighs_load_by_device_speed() {
        let eta = CostModelEta;
        // device 0 is 3x slower per request; with equal load the faster
        // device wins...
        let fleet = [snap(0, true, 0, Some(3.0)), snap(1, true, 0, Some(1.0))];
        assert_eq!(eta.pick(&key(), &fleet), Some(1));
        // ...until its backlog makes the slow device the earlier finisher.
        let fleet = [snap(0, true, 0, Some(3.0)), snap(1, true, 5, Some(1.0))];
        assert_eq!(eta.pick(&key(), &fleet), Some(0));
        // members without estimates lose to members with them
        let fleet = [snap(0, true, 0, None), snap(1, true, 9, Some(1.0))];
        assert_eq!(eta.pick(&key(), &fleet), Some(1));
        // but are still eligible when nothing has an estimate
        let fleet = [snap(0, true, 4, None), snap(1, true, 2, None)];
        assert_eq!(eta.pick(&key(), &fleet), Some(1));
    }

    #[test]
    fn by_name_resolves_and_rejects() {
        for (name, want) in [
            ("round-robin", "round-robin"),
            ("least-loaded", "least-loaded"),
            ("cost-eta", "cost-eta"),
            ("eta", "cost-eta"),
        ] {
            assert_eq!(scheduler_by_name(name).unwrap().name(), want);
        }
        let err = scheduler_by_name("random").unwrap_err().to_string();
        assert!(err.contains("unknown scheduler 'random'"), "{err}");
        assert!(err.contains("least-loaded"), "must name alternatives: {err}");
    }

    #[test]
    fn cost_meter_prices_tiles_differently_per_device() {
        let entry = |tile: TileDim| ArtifactEntry {
            name: format!("t{tile}"),
            kernel: Interpolator::Bilinear,
            src: (64, 64),
            scale: 2,
            batch: 1,
            tile,
            path: "x".into(),
        };
        let gtx = CostMeter::new(find_device("gtx260").unwrap(), Arc::new(SimCostModel));
        let fermi = CostMeter::new(find_device("fermi").unwrap(), Arc::new(SimCostModel));
        let wide = entry(TileDim::new(16, 8));
        let tall = entry(TileDim::new(32, 16));
        // The cross-device flip the fleet acceptance test relies on:
        // gtx260 prefers 16x8, fermi prefers 32x16 at this shape.
        assert!(gtx.ms_of(&wide) < gtx.ms_of(&tall));
        assert!(fermi.ms_of(&tall) < fermi.ms_of(&wide));
    }
}
