//! Device scheduling: which fleet member serves the next request.
//!
//! The [`Fleet`](super::Fleet) snapshots every member's state into a
//! [`DeviceSnapshot`] slice and asks the configured [`Scheduler`] to pick
//! one. Members that cannot route the request's key (`supports == false`)
//! must never be picked — every implementation filters on it, and the
//! service double-checks before admitting.
//!
//! Three built-ins cover the obvious operating points:
//!
//! * [`RoundRobin`] — fair rotation; the baseline.
//! * [`LeastLoaded`] — pick the member with the fewest unanswered
//!   requests (queue + in-flight).
//! * [`CostModelEta`] — pick the member with the smallest estimated
//!   completion time `(load / slots + 1) × cost_ms`, where `cost_ms` is
//!   the [`CostModel`](crate::autotuner::CostModel) (by default the
//!   timing simulator) estimate of serving this key on that device
//!   *through the tile its router prefers*, and `slots` is how many
//!   requests the member executes concurrently (workers × batch cap) —
//!   so a device whose tuned tile is fast for this shape attracts
//!   proportionally more traffic.

use super::request::RequestKey;
use crate::autotuner::CostModel;
use crate::device::DeviceDescriptor;
use crate::runtime::ArtifactEntry;
use crate::sim::Launch;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// One fleet member's state at scheduling time.
///
/// Owns no borrow into the fleet: `device_id` is a shared `Arc<str>`
/// clone, so snapshot slices can live in reusable thread-local buffers
/// across submits (the hot path refills one buffer instead of
/// allocating a `Vec` per request).
#[derive(Debug, Clone)]
pub struct DeviceSnapshot {
    /// Index into the service's member list.
    pub index: usize,
    /// Device id (or a synthetic label for anonymous members).
    pub device_id: Arc<str>,
    /// Can this member's router serve the request key?
    pub supports: bool,
    /// Requests admitted to this member and not yet answered — this
    /// already includes everything still sitting in its admission
    /// queue, so it IS the member's total backlog.
    pub inflight: u64,
    /// Cost-model estimate (ms) of one request of this key on this
    /// member's preferred tile variant; `None` when no estimate exists.
    pub cost_ms: Option<f64>,
    /// Requests this member executes concurrently (worker threads ×
    /// dynamic batch cap); divides the backlog in ETA estimates.
    pub slots: u64,
    /// Requests currently waiting in this member's admission queue —
    /// the slice of `inflight` a thief can actually take from.
    pub queued: u64,
    /// True when fleet-level work-stealing is on and this member's
    /// queued backlog has reached the steal threshold: idle peers will
    /// pull work out of its queue, so ETA estimates may discount its
    /// backlog by the peers' idle capacity (see [`steal_discount`]).
    pub stealable: bool,
}

impl DeviceSnapshot {
    /// Total unanswered load on this member.
    pub fn load(&self) -> u64 {
        self.inflight
    }
}

/// Picks the serving device for one request.
pub trait Scheduler: Send + Sync {
    /// Return the `index` of a member with `supports == true`, or `None`
    /// when no member can serve the key.
    fn pick(&self, key: &RequestKey, fleet: &[DeviceSnapshot]) -> Option<usize>;

    /// Queue-depth-aware estimate (ms) of the soonest ANY supporting
    /// member could answer one request of `key`, or `None` when this
    /// scheduler has no cost information. The service uses it for
    /// deadline-aware admission: a request whose budget is below this
    /// floor is declined up front with
    /// [`SubmitError::Infeasible`](super::SubmitError) instead of being
    /// accepted and shed later. Default: no estimate (never declines).
    fn min_eta_ms(&self, _key: &RequestKey, _fleet: &[DeviceSnapshot]) -> Option<f64> {
        None
    }

    /// Label for reports and `tilekit serve` output.
    fn name(&self) -> &'static str;
}

/// Fair rotation over supporting members.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: AtomicUsize,
}

impl Scheduler for RoundRobin {
    fn pick(&self, _key: &RequestKey, fleet: &[DeviceSnapshot]) -> Option<usize> {
        if fleet.is_empty() {
            return None;
        }
        let start = self.next.fetch_add(1, Ordering::Relaxed);
        (0..fleet.len())
            .map(|i| &fleet[(start + i) % fleet.len()])
            .find(|s| s.supports)
            .map(|s| s.index)
    }

    fn name(&self) -> &'static str {
        "round-robin"
    }
}

/// Pick the supporting member with the least unanswered load (ties break
/// toward the lower index, keeping the choice deterministic).
#[derive(Debug, Default)]
pub struct LeastLoaded;

impl Scheduler for LeastLoaded {
    fn pick(&self, _key: &RequestKey, fleet: &[DeviceSnapshot]) -> Option<usize> {
        fleet
            .iter()
            .filter(|s| s.supports)
            .min_by_key(|s| (s.load(), s.index))
            .map(|s| s.index)
    }

    fn name(&self) -> &'static str {
        "least-loaded"
    }
}

/// Pick the member with the smallest estimated completion time
/// `(load / slots + 1) × cost_ms`. Members without a cost estimate rank
/// last (but are still eligible — a fleet mixing simulated and opaque
/// backends degrades to least-loaded among the opaque ones).
#[derive(Debug, Default)]
pub struct CostModelEta;

/// The steal-aware backlog discount: how many of this member's queued
/// requests its peers' idle capacity is about to drain. A hot member
/// whose backlog already crossed the steal threshold (`stealable`) will
/// be relieved by idle thieves, so pricing its full backlog into the ETA
/// over-penalizes it and the scheduler keeps dog-piling the idle
/// members instead. The discount is bounded twice:
///
/// * by this member's **fair share of the peers' idle capacity** — the
///   sum over supporting peers of `slots - load` (a busy peer steals
///   nothing), divided by how many members are currently stealable:
///   several hot queues compete for the same thieves, and crediting
///   each with the full idle pool would under-price all of them at
///   once;
/// * by **half this member's queued backlog** — thieves take from the
///   admission queue only, never from work already executing, and the
///   steal policy never takes more than half a victim's queue per
///   attempt (see [`select_steals`](super::stealing::select_steals)).
///
/// Zero when the member is not `stealable` (stealing off, backlog under
/// the threshold, or a single-member fleet).
pub fn steal_discount(s: &DeviceSnapshot, fleet: &[DeviceSnapshot]) -> u64 {
    if !s.stealable {
        return 0;
    }
    let idle: u64 = fleet
        .iter()
        .filter(|p| p.index != s.index && p.supports)
        .map(|p| p.slots.saturating_sub(p.load()))
        .sum();
    let victims = fleet.iter().filter(|p| p.stealable).count().max(1) as u64;
    (idle / victims).min(s.queued / 2)
}

/// Estimated completion time (ms) of one more request on this member:
/// its backlog — discounted by what peers' stealing will drain
/// ([`steal_discount`]) — divided by its execution parallelism, plus the
/// new request itself, each at the member's per-request cost. `None`
/// when the member has no cost estimate. The parallelism division
/// matters most for the *absolute* infeasibility floor
/// ([`Scheduler::min_eta_ms`]): a serial estimate would wrongly decline
/// deadlines a multi-worker member can in fact meet.
fn eta_ms(s: &DeviceSnapshot, fleet: &[DeviceSnapshot]) -> Option<f64> {
    let slots = s.slots.max(1) as f64;
    let load = s.load().saturating_sub(steal_discount(s, fleet)) as f64;
    s.cost_ms.map(|c| (load / slots + 1.0) * c)
}

impl Scheduler for CostModelEta {
    fn pick(&self, _key: &RequestKey, fleet: &[DeviceSnapshot]) -> Option<usize> {
        fleet
            .iter()
            .filter(|s| s.supports)
            .min_by(|a, b| {
                let eta = |s: &DeviceSnapshot| eta_ms(s, fleet).unwrap_or(f64::INFINITY);
                eta(a)
                    .total_cmp(&eta(b))
                    .then_with(|| a.load().cmp(&b.load()))
                    .then_with(|| a.index.cmp(&b.index))
            })
            .map(|s| s.index)
    }

    /// The deadline-aware floor: the best queue-depth-aware ETA any
    /// supporting member offers. `None` when no supporting member has a
    /// cost estimate (an opaque fleet cannot prove a budget infeasible).
    fn min_eta_ms(&self, _key: &RequestKey, fleet: &[DeviceSnapshot]) -> Option<f64> {
        fleet
            .iter()
            .filter(|s| s.supports)
            .filter_map(|s| eta_ms(s, fleet))
            .filter(|eta| eta.is_finite())
            .min_by(f64::total_cmp)
    }

    fn name(&self) -> &'static str {
        "cost-eta"
    }
}

/// Deterministically route `percent`% of traffic to one member (`hot`),
/// spreading the rest round-robin over the other supporting members.
/// Not a production scheduler: it reproduces the skewed / hot-spot
/// routing that the work-stealing tests and the adaptive-fleet demo
/// need, while staying deterministic.
#[derive(Debug)]
pub struct Biased {
    hot: usize,
    percent: usize,
    count: AtomicUsize,
}

impl Biased {
    /// Send `percent`% (0..=100) of requests to member index `hot`.
    pub fn new(hot: usize, percent: usize) -> Biased {
        assert!(percent <= 100, "percent must be 0..=100");
        Biased {
            hot,
            percent,
            count: AtomicUsize::new(0),
        }
    }
}

impl Scheduler for Biased {
    fn pick(&self, _key: &RequestKey, fleet: &[DeviceSnapshot]) -> Option<usize> {
        let n = self.count.fetch_add(1, Ordering::Relaxed);
        let hot = fleet.iter().find(|s| s.index == self.hot && s.supports);
        if let Some(h) = hot {
            if n % 100 < self.percent {
                return Some(h.index);
            }
        }
        let others: Vec<&DeviceSnapshot> = fleet
            .iter()
            .filter(|s| s.supports && s.index != self.hot)
            .collect();
        if others.is_empty() {
            return hot.map(|h| h.index);
        }
        Some(others[n % others.len()].index)
    }

    fn name(&self) -> &'static str {
        "biased"
    }
}

/// Resolve a scheduler by CLI/config name.
pub fn scheduler_by_name(name: &str) -> Result<Box<dyn Scheduler>> {
    match name {
        "round-robin" | "rr" => Ok(Box::new(RoundRobin::default())),
        "least-loaded" | "ll" => Ok(Box::new(LeastLoaded)),
        "cost-eta" | "eta" => Ok(Box::new(CostModelEta)),
        other => bail!(
            "unknown scheduler '{other}' (expected one of: round-robin, least-loaded, cost-eta)"
        ),
    }
}

/// Per-device cost oracle: estimates (via a [`CostModel`], by default the
/// timing simulator) how long one request takes through a given artifact
/// variant on this device. The service uses it to build the
/// [`CostModelEta`] estimate table; workers use it to meter the
/// aggregate sim cost a simulated fleet accumulates.
pub struct CostMeter {
    device: DeviceDescriptor,
    model: Arc<dyn CostModel + Send + Sync>,
}

impl CostMeter {
    pub fn new(device: DeviceDescriptor, model: Arc<dyn CostModel + Send + Sync>) -> CostMeter {
        CostMeter { device, model }
    }

    /// The device this meter prices for.
    pub fn device(&self) -> &DeviceDescriptor {
        &self.device
    }

    /// Estimated time (ms) of ONE request through `entry` on this
    /// device: the sim cost of the entry's tile at the entry's shape.
    pub fn ms_of(&self, entry: &ArtifactEntry) -> f64 {
        let launch = Launch {
            kernel: entry.kernel,
            tile: entry.tile,
            // ArtifactEntry.src is (h, w); Launch wants w/h.
            src_w: entry.src.1,
            src_h: entry.src.0,
            scale: entry.scale,
        };
        self.model.evaluate(&launch, &self.device).ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotuner::SimCostModel;
    use crate::device::find_device;
    use crate::image::Interpolator;
    use crate::tiling::TileDim;

    fn key() -> RequestKey {
        RequestKey {
            kernel: Interpolator::Bilinear,
            src: (64, 64),
            scale: 2,
        }
    }

    fn snap(
        index: usize,
        supports: bool,
        inflight: u64,
        cost_ms: Option<f64>,
    ) -> DeviceSnapshot {
        DeviceSnapshot {
            index,
            device_id: "d".into(),
            supports,
            inflight,
            cost_ms,
            // Serial member: (load + 1) × cost, the simplest ETA shape.
            // Tests treat the whole backlog as still queued.
            slots: 1,
            queued: inflight,
            stealable: false,
        }
    }

    #[test]
    fn round_robin_rotates_over_supporting() {
        let rr = RoundRobin::default();
        let fleet = [snap(0, true, 0, None), snap(1, false, 0, None), snap(2, true, 0, None)];
        let picks: Vec<usize> = (0..4).map(|_| rr.pick(&key(), &fleet).unwrap()).collect();
        // starts rotate 0,1,2,3; member 1 never serves, the scan lands on
        // the next supporting member each time
        assert_eq!(picks, vec![0, 2, 2, 0], "skips the unsupporting member");
        assert!(picks.iter().all(|&i| i != 1));
        assert!(rr.pick(&key(), &[snap(0, false, 0, None)]).is_none());
        assert!(rr.pick(&key(), &[]).is_none());
    }

    #[test]
    fn least_loaded_prefers_idle() {
        let ll = LeastLoaded;
        let fleet = [snap(0, true, 5, None), snap(1, true, 2, None), snap(2, false, 0, None)];
        assert_eq!(ll.pick(&key(), &fleet), Some(1));
        // ties break toward the lower index, deterministically
        let fleet = [snap(0, true, 3, None), snap(1, true, 3, None)];
        assert_eq!(ll.pick(&key(), &fleet), Some(0));
    }

    #[test]
    fn cost_eta_weighs_load_by_device_speed() {
        let eta = CostModelEta;
        // device 0 is 3x slower per request; with equal load the faster
        // device wins...
        let fleet = [snap(0, true, 0, Some(3.0)), snap(1, true, 0, Some(1.0))];
        assert_eq!(eta.pick(&key(), &fleet), Some(1));
        // ...until its backlog makes the slow device the earlier finisher.
        let fleet = [snap(0, true, 0, Some(3.0)), snap(1, true, 5, Some(1.0))];
        assert_eq!(eta.pick(&key(), &fleet), Some(0));
        // members without estimates lose to members with them
        let fleet = [snap(0, true, 0, None), snap(1, true, 9, Some(1.0))];
        assert_eq!(eta.pick(&key(), &fleet), Some(1));
        // but are still eligible when nothing has an estimate
        let fleet = [snap(0, true, 4, None), snap(1, true, 2, None)];
        assert_eq!(eta.pick(&key(), &fleet), Some(1));
    }

    #[test]
    fn min_eta_is_queue_depth_aware() {
        let eta = CostModelEta;
        // Idle fast member: floor = 1 * 1.0.
        let fleet = [snap(0, true, 0, Some(3.0)), snap(1, true, 0, Some(1.0))];
        assert_eq!(eta.min_eta_ms(&key(), &fleet), Some(1.0));
        // Backlog raises the floor: (5+1)*1 vs (0+1)*3 -> 3.0.
        let fleet = [snap(0, true, 0, Some(3.0)), snap(1, true, 5, Some(1.0))];
        assert_eq!(eta.min_eta_ms(&key(), &fleet), Some(3.0));
        // Unsupporting members don't count.
        let fleet = [snap(0, false, 0, Some(0.1)), snap(1, true, 0, Some(2.0))];
        assert_eq!(eta.min_eta_ms(&key(), &fleet), Some(2.0));
        // Execution parallelism divides the backlog: 8 queued on a
        // 4-slot member is only two waves ahead of the new request.
        let mut wide = snap(0, true, 8, Some(1.0));
        wide.slots = 4;
        assert_eq!(eta.min_eta_ms(&key(), &[wide]), Some(3.0));
        // No estimates -> no floor (cannot prove infeasibility)...
        let fleet = [snap(0, true, 9, None)];
        assert_eq!(eta.min_eta_ms(&key(), &fleet), None);
        // ...and schedulers without cost information never offer one.
        assert_eq!(LeastLoaded.min_eta_ms(&key(), &fleet), None);
        assert_eq!(RoundRobin::default().min_eta_ms(&key(), &fleet), None);
    }

    #[test]
    fn steal_discount_math() {
        // Not stealable -> no discount, whatever the peers look like.
        let fleet = [snap(0, true, 10, Some(1.0)), snap(1, true, 0, Some(1.0))];
        assert_eq!(steal_discount(&fleet[0], &fleet), 0);
        // Stealable: discounted by the peers' idle capacity...
        let mut hot = snap(0, true, 10, Some(1.0));
        hot.stealable = true;
        let mut idle_peer = snap(1, true, 1, Some(1.0));
        idle_peer.slots = 4; // 3 idle slots
        let fleet = [hot.clone(), idle_peer];
        assert_eq!(steal_discount(&fleet[0], &fleet), 3);
        // ...capped at half the hot member's own backlog (the steal
        // policy never takes more than half a victim's queue)...
        let mut wide_peer = snap(1, true, 0, Some(1.0));
        wide_peer.slots = 100;
        let fleet = [hot.clone(), wide_peer];
        assert_eq!(steal_discount(&fleet[0], &fleet), 5);
        // ...a saturated peer contributes nothing...
        let busy_peer = snap(1, true, 9, Some(1.0)); // slots 1, load 9
        let fleet = [hot.clone(), busy_peer];
        assert_eq!(steal_discount(&fleet[0], &fleet), 0);
        // ...a peer that cannot route the key cannot steal it...
        let mut blind_peer = snap(1, false, 0, Some(1.0));
        blind_peer.slots = 100;
        let fleet = [hot, blind_peer];
        assert_eq!(steal_discount(&fleet[0], &fleet), 0);
        // ...only the QUEUED slice is stealable: 24 in flight but just
        // 4 still queued caps the discount at 4/2, however much idle
        // capacity the peers have...
        let mut executing = snap(0, true, 24, Some(1.0));
        executing.stealable = true;
        executing.queued = 4;
        let mut wide = snap(1, true, 0, Some(1.0));
        wide.slots = 100;
        let fleet = [executing, wide];
        assert_eq!(steal_discount(&fleet[0], &fleet), 2);
        // ...and concurrent victims split the idle pool instead of each
        // claiming all of it: two stealable hot members + one peer with
        // 6 idle slots -> 3 each, never 6 + 6 from 6.
        let mut hot_a = snap(0, true, 10, Some(1.0));
        hot_a.stealable = true;
        let mut hot_b = snap(1, true, 10, Some(1.0));
        hot_b.stealable = true;
        let mut helper = snap(2, true, 0, Some(1.0));
        helper.slots = 6;
        let fleet = [hot_a, hot_b, helper];
        assert_eq!(steal_discount(&fleet[0], &fleet), 3);
        assert_eq!(steal_discount(&fleet[1], &fleet), 3);
    }

    #[test]
    fn cost_eta_discounts_stealable_backlog() {
        let eta = CostModelEta;
        // Without the discount the idle-but-3x-slower device 1 wins:
        // (8+1)*1.0 = 9.0 vs (0+1)*3.0 = 3.0.
        let fleet = [snap(0, true, 8, Some(1.0)), snap(1, true, 0, Some(3.0))];
        assert_eq!(eta.pick(&key(), &fleet), Some(1));
        assert_eq!(eta.min_eta_ms(&key(), &fleet), Some(3.0));
        // Mark the hot member stealable with an idle peer (8 slots):
        // discount = min(8 idle, 8/2) = 4, so the hot member prices at
        // (8-4+1)*1.0 = 5.0 — better than its raw 9.0 but still behind
        // the idle member's 3.0, so the pick and the floor hold.
        let mut hot = snap(0, true, 8, Some(1.0));
        hot.stealable = true;
        let mut peer = snap(1, true, 0, Some(3.0));
        peer.slots = 8;
        let fleet = [hot, peer];
        assert_eq!(
            eta.min_eta_ms(&key(), &fleet),
            Some(3.0),
            "floor is still the idle member"
        );
        // With a cheap enough discounted ETA the hot member is picked
        // again instead of dog-piling the slow idle peer: discounted
        // (8 - 4 + 1) * 0.5 = 2.5 < 3.0.
        let mut hot = snap(0, true, 8, Some(0.5));
        hot.stealable = true;
        let mut peer = snap(1, true, 0, Some(3.0));
        peer.slots = 8;
        let fleet = [hot.clone(), peer.clone()];
        assert_eq!(eta.pick(&key(), &fleet), Some(0));
        assert_eq!(eta.min_eta_ms(&key(), &fleet), Some(2.5));
        // The same fleet with stealing off keeps the old (over-)penalty.
        hot.stealable = false;
        let fleet = [hot, peer];
        assert_eq!(eta.pick(&key(), &fleet), Some(1));
    }

    #[test]
    fn biased_skews_deterministically() {
        let b = Biased::new(0, 80);
        let fleet = [snap(0, true, 0, None), snap(1, true, 0, None)];
        let picks: Vec<usize> = (0..100).map(|_| b.pick(&key(), &fleet).unwrap()).collect();
        let hot = picks.iter().filter(|&&i| i == 0).count();
        assert_eq!(hot, 80, "exactly 80% of 100 picks hit the hot member");
        // When the hot member cannot serve the key, traffic spills over.
        let b = Biased::new(0, 100);
        let fleet = [snap(0, false, 0, None), snap(1, true, 0, None)];
        assert_eq!(b.pick(&key(), &fleet), Some(1));
        // Nobody supports -> None.
        let fleet = [snap(0, false, 0, None), snap(1, false, 0, None)];
        assert_eq!(b.pick(&key(), &fleet), None);
    }

    #[test]
    fn by_name_resolves_and_rejects() {
        for (name, want) in [
            ("round-robin", "round-robin"),
            ("least-loaded", "least-loaded"),
            ("cost-eta", "cost-eta"),
            ("eta", "cost-eta"),
        ] {
            assert_eq!(scheduler_by_name(name).unwrap().name(), want);
        }
        let err = scheduler_by_name("random").unwrap_err().to_string();
        assert!(err.contains("unknown scheduler 'random'"), "{err}");
        assert!(err.contains("least-loaded"), "must name alternatives: {err}");
    }

    #[test]
    fn cost_meter_prices_tiles_differently_per_device() {
        let entry = |tile: TileDim| ArtifactEntry {
            name: format!("t{tile}"),
            kernel: Interpolator::Bilinear,
            src: (64, 64),
            scale: 2,
            batch: 1,
            tile,
            path: "x".into(),
        };
        let gtx = CostMeter::new(find_device("gtx260").unwrap(), Arc::new(SimCostModel));
        let fermi = CostMeter::new(find_device("fermi").unwrap(), Arc::new(SimCostModel));
        let wide = entry(TileDim::new(16, 8));
        let tall = entry(TileDim::new(32, 16));
        // The cross-device flip the fleet acceptance test relies on:
        // gtx260 prefers 16x8, fermi prefers 32x16 at this shape.
        assert!(gtx.ms_of(&wide) < gtx.ms_of(&tall));
        assert!(fermi.ms_of(&tall) < fermi.ms_of(&wide));
    }
}
