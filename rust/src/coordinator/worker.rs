//! The worker stage: a pool of threads pulling flushed batches, shedding
//! cancelled/expired requests before they cost anything, routing the
//! rest to an artifact, splitting oversize groups to the artifact's
//! static batch, executing through the [`ResizeBackend`], and replying
//! per request. When a [`CostMeter`] is attached (simulated fleets) the
//! executed requests' sim cost accumulates into the stats.

use super::batcher::Batch;
use super::router::{Router, SharedRouter};
use super::scheduler::CostMeter;
use super::stats::ServingStats;
use crate::exec::Receiver;
use crate::runtime::ResizeBackend;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Spawn `n` workers draining `rx`. They exit when the channel closes.
/// The router is snapshotted per batch: a retune hot-swap applies to
/// the next batch without draining the pool.
pub fn spawn_workers(
    n: usize,
    rx: Receiver<Batch>,
    router: SharedRouter,
    backend: Arc<dyn ResizeBackend>,
    stats: Arc<ServingStats>,
    meter: Option<Arc<CostMeter>>,
) -> Vec<JoinHandle<()>> {
    (0..n.max(1))
        .map(|i| {
            let rx = rx.clone();
            let router = Arc::clone(&router);
            let backend = Arc::clone(&backend);
            let stats = Arc::clone(&stats);
            let meter = meter.clone();
            std::thread::Builder::new()
                .name(format!("tilekit-exec-{i}"))
                .spawn(move || {
                    // Compile/prepare everything BEFORE serving: the
                    // request path must never pay first-use compilation.
                    if let Err(e) = backend.warm() {
                        eprintln!("worker {i}: backend warmup failed: {e:#}");
                    }
                    while let Ok(batch) = rx.recv() {
                        let current = Arc::clone(&router.read().expect("router lock"));
                        run_batch(batch, &current, backend.as_ref(), &stats, meter.as_deref());
                    }
                })
                .expect("spawn worker")
        })
        .collect()
}

/// Execute one flushed batch (possibly splitting across artifact
/// invocations) and deliver replies. Public so tests and the e2e bench
/// can drive it synchronously.
pub fn run_batch(
    batch: Batch,
    router: &Router,
    backend: &dyn ResizeBackend,
    stats: &ServingStats,
    meter: Option<&CostMeter>,
) {
    let key = batch.key;
    // Shed requests that no longer need (cancelled) or can no longer
    // meet (expired deadline) execution — BEFORE they reach the backend.
    let now = Instant::now();
    let mut requests = Vec::with_capacity(batch.requests.len());
    for r in batch.requests {
        if r.is_cancelled() {
            stats.cancelled.inc();
            let _ = r
                .reply
                .send(Err(anyhow::anyhow!("request {} cancelled", r.id)));
        } else if r.is_expired(now) {
            stats.shed.inc();
            let _ = r.reply.send(Err(anyhow::anyhow!(
                "request {} deadline exceeded before execution",
                r.id
            )));
        } else {
            requests.push(r);
        }
    }
    while !requests.is_empty() {
        let entry = match router.route(&key, requests.len()) {
            Ok(e) => e,
            Err(err) => {
                // No artifact: fail the whole group.
                let msg = err.to_string();
                for r in requests.drain(..) {
                    stats.failed.inc();
                    let _ = r.reply.send(Err(anyhow::anyhow!("{msg}")));
                }
                return;
            }
        };
        let take = requests.len().min(entry.batch as usize);
        let chunk: Vec<_> = requests.drain(..take).collect();
        let images: Vec<_> = chunk.iter().map(|r| r.image.clone()).collect();

        let exec_start = Instant::now();
        for r in &chunk {
            stats.record_queue_wait(r.priority, exec_start.duration_since(r.admitted));
        }
        let result = backend.run_batch(entry, &images);
        stats.exec_time.record(exec_start.elapsed());
        stats.batches.inc();
        stats.batched.add(chunk.len() as u64);
        if let Some(m) = meter {
            // Per-request sim cost of the variant this device routed to.
            let ms = m.ms_of(entry);
            for _ in &chunk {
                stats.record_sim_cost_ms(ms);
            }
        }

        match result {
            Ok(outputs) => {
                debug_assert_eq!(outputs.len(), chunk.len());
                for (r, out) in chunk.into_iter().zip(outputs) {
                    stats.completed.inc();
                    stats.record_latency(r.priority, r.admitted.elapsed());
                    let _ = r.reply.send(Ok(out));
                }
            }
            Err(err) => {
                let msg = err.to_string();
                for r in chunk {
                    stats.failed.inc();
                    stats.record_latency(r.priority, r.admitted.elapsed());
                    let _ = r.reply.send(Err(anyhow::anyhow!("{msg}")));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotuner::SimCostModel;
    use crate::coordinator::request::{Priority, RequestKey, ResizeRequest, Ticket};
    use crate::coordinator::TilePolicy;
    use crate::device::find_device;
    use crate::image::{generate, Interpolator};
    use crate::runtime::{Manifest, MockEngine};
    use std::path::PathBuf;
    use std::time::Duration;

    fn manifest() -> Manifest {
        Manifest::parse(
            r#"{
              "version": 1,
              "artifacts": [
                {"name": "bl_s2_b1", "kernel": "bilinear", "src": [16, 16],
                 "scale": 2, "batch": 1, "tile": [4, 32], "path": "x"},
                {"name": "bl_s2_b4", "kernel": "bilinear", "src": [16, 16],
                 "scale": 2, "batch": 4, "tile": [4, 32], "path": "x"}
              ]
            }"#,
            PathBuf::from("."),
        )
        .unwrap()
    }

    fn make_batch(n: usize) -> (Batch, Vec<Ticket>) {
        let img = generate::test_scene(16, 16, 1);
        let key = RequestKey::of(Interpolator::Bilinear, &img, 2);
        let mut tickets = Vec::new();
        let requests = (0..n)
            .map(|i| {
                let (t, tx) = Ticket::new(i as u64);
                tickets.push(t);
                ResizeRequest::bare(i as u64, key, img.clone(), tx)
            })
            .collect();
        (Batch { key, requests }, tickets)
    }

    #[test]
    fn executes_and_replies() {
        let router = Router::new(&manifest(), TilePolicy::PortableFallback);
        let backend = MockEngine::new();
        let stats = ServingStats::new();
        let (batch, tickets) = make_batch(3);
        run_batch(batch, &router, &backend, &stats, None);
        for t in tickets {
            let out = t.wait().unwrap();
            assert_eq!(out.width(), 32);
        }
        assert_eq!(stats.completed.get(), 3);
        assert_eq!(stats.batches.get(), 1);
        assert_eq!(stats.mean_batch(), 3.0);
        assert_eq!(
            stats.latency_by_class[Priority::Interactive.index()].count(),
            3,
            "bare requests are interactive-class"
        );
    }

    #[test]
    fn splits_oversize_groups() {
        let router = Router::new(&manifest(), TilePolicy::PortableFallback);
        let backend = MockEngine::new();
        let stats = ServingStats::new();
        let (batch, tickets) = make_batch(10); // max artifact batch = 4
        run_batch(batch, &router, &backend, &stats, None);
        for t in tickets {
            t.wait().unwrap();
        }
        assert_eq!(stats.completed.get(), 10);
        assert_eq!(stats.batches.get(), 3); // 4 + 4 + 2
    }

    #[test]
    fn backend_failure_propagates() {
        let router = Router::new(&manifest(), TilePolicy::PortableFallback);
        let backend = MockEngine::failing_every(1); // every batch fails
        let stats = ServingStats::new();
        let (batch, tickets) = make_batch(2);
        run_batch(batch, &router, &backend, &stats, None);
        for t in tickets {
            assert!(t.wait().is_err());
        }
        assert_eq!(stats.failed.get(), 2);
        assert_eq!(stats.completed.get(), 0);
    }

    #[test]
    fn unroutable_key_fails_cleanly() {
        let router = Router::new(&manifest(), TilePolicy::PortableFallback);
        let backend = MockEngine::new();
        let stats = ServingStats::new();
        let img = generate::gradient(8, 8); // no 8x8 artifact
        let key = RequestKey::of(Interpolator::Bilinear, &img, 2);
        let (t, tx) = Ticket::new(0);
        let batch = Batch {
            key,
            requests: vec![ResizeRequest::bare(0, key, img, tx)],
        };
        run_batch(batch, &router, &backend, &stats, None);
        assert!(t.wait().is_err());
        assert_eq!(stats.failed.get(), 1);
    }

    #[test]
    fn cancelled_and_expired_requests_never_reach_the_backend() {
        let router = Router::new(&manifest(), TilePolicy::PortableFallback);
        let backend = MockEngine::new();
        let stats = ServingStats::new();
        let (mut batch, tickets) = make_batch(3);
        // request 0: cancelled; request 1: expired; request 2: healthy
        batch.requests[0].cancel.cancel();
        batch.requests[1].deadline = Some(Instant::now() - Duration::from_millis(1));
        run_batch(batch, &router, &backend, &stats, None);
        let mut it = tickets.into_iter();
        let t0 = it.next().unwrap();
        let t1 = it.next().unwrap();
        let t2 = it.next().unwrap();
        assert!(t0.wait().unwrap_err().to_string().contains("cancelled"));
        assert!(t1.wait().unwrap_err().to_string().contains("deadline"));
        assert!(t2.wait().is_ok());
        assert_eq!(stats.cancelled.get(), 1);
        assert_eq!(stats.shed.get(), 1);
        assert_eq!(stats.completed.get(), 1);
        assert_eq!(
            backend.executed.get(),
            1,
            "only the healthy request executes"
        );
    }

    #[test]
    fn meter_accumulates_sim_cost_per_request() {
        let router = Router::new(&manifest(), TilePolicy::PortableFallback);
        let backend = MockEngine::new();
        let stats = ServingStats::new();
        let meter = CostMeter::new(
            find_device("gtx260").unwrap(),
            std::sync::Arc::new(SimCostModel),
        );
        let (batch, tickets) = make_batch(4);
        run_batch(batch, &router, &backend, &stats, Some(&meter));
        for t in tickets {
            t.wait().unwrap();
        }
        assert!(stats.sim_cost_ns.get() > 0, "metered run records cost");
        // 4 requests through one variant: cost divides evenly
        assert_eq!(stats.sim_cost_ns.get() % 4, 0);
    }
}
