//! The worker stage: a pool of threads pulling flushed batches, routing
//! them to an artifact, splitting oversize groups to the artifact's
//! static batch, executing through the [`ResizeBackend`], and replying
//! per request.

use super::batcher::Batch;
use super::router::Router;
use super::stats::ServingStats;
use crate::exec::Receiver;
use crate::runtime::ResizeBackend;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Spawn `n` workers draining `rx`. They exit when the channel closes.
pub fn spawn_workers(
    n: usize,
    rx: Receiver<Batch>,
    router: Arc<Router>,
    backend: Arc<dyn ResizeBackend>,
    stats: Arc<ServingStats>,
) -> Vec<JoinHandle<()>> {
    (0..n.max(1))
        .map(|i| {
            let rx = rx.clone();
            let router = Arc::clone(&router);
            let backend = Arc::clone(&backend);
            let stats = Arc::clone(&stats);
            std::thread::Builder::new()
                .name(format!("tilekit-exec-{i}"))
                .spawn(move || {
                    // Compile/prepare everything BEFORE serving: the
                    // request path must never pay first-use compilation.
                    if let Err(e) = backend.warm() {
                        eprintln!("worker {i}: backend warmup failed: {e:#}");
                    }
                    while let Ok(batch) = rx.recv() {
                        run_batch(batch, &router, backend.as_ref(), &stats);
                    }
                })
                .expect("spawn worker")
        })
        .collect()
}

/// Execute one flushed batch (possibly splitting across artifact
/// invocations) and deliver replies. Public so tests and the e2e bench
/// can drive it synchronously.
pub fn run_batch(
    batch: Batch,
    router: &Router,
    backend: &dyn ResizeBackend,
    stats: &ServingStats,
) {
    let key = batch.key;
    let mut requests = batch.requests;
    while !requests.is_empty() {
        let entry = match router.route(&key, requests.len()) {
            Ok(e) => e,
            Err(err) => {
                // No artifact: fail the whole group.
                let msg = err.to_string();
                for r in requests.drain(..) {
                    stats.failed.inc();
                    let _ = r.reply.send(Err(anyhow::anyhow!("{msg}")));
                }
                return;
            }
        };
        let take = requests.len().min(entry.batch as usize);
        let chunk: Vec<_> = requests.drain(..take).collect();
        let images: Vec<_> = chunk.iter().map(|r| r.image.clone()).collect();

        let exec_start = Instant::now();
        for r in &chunk {
            stats
                .queue_wait
                .record(exec_start.duration_since(r.admitted));
        }
        let result = backend.run_batch(entry, &images);
        stats.exec_time.record(exec_start.elapsed());
        stats.batches.inc();
        stats.batched.add(chunk.len() as u64);

        match result {
            Ok(outputs) => {
                debug_assert_eq!(outputs.len(), chunk.len());
                for (r, out) in chunk.into_iter().zip(outputs) {
                    stats.completed.inc();
                    stats.latency.record(r.admitted.elapsed());
                    let _ = r.reply.send(Ok(out));
                }
            }
            Err(err) => {
                let msg = err.to_string();
                for r in chunk {
                    stats.failed.inc();
                    stats.latency.record(r.admitted.elapsed());
                    let _ = r.reply.send(Err(anyhow::anyhow!("{msg}")));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{RequestKey, ResizeRequest, Ticket};
    use crate::coordinator::TilePolicy;
    use crate::image::{generate, Interpolator};
    use crate::runtime::{Manifest, MockEngine};
    use std::path::PathBuf;

    fn manifest() -> Manifest {
        Manifest::parse(
            r#"{
              "version": 1,
              "artifacts": [
                {"name": "bl_s2_b1", "kernel": "bilinear", "src": [16, 16],
                 "scale": 2, "batch": 1, "tile": [4, 32], "path": "x"},
                {"name": "bl_s2_b4", "kernel": "bilinear", "src": [16, 16],
                 "scale": 2, "batch": 4, "tile": [4, 32], "path": "x"}
              ]
            }"#,
            PathBuf::from("."),
        )
        .unwrap()
    }

    fn make_batch(n: usize) -> (Batch, Vec<Ticket>) {
        let img = generate::test_scene(16, 16, 1);
        let key = RequestKey::of(Interpolator::Bilinear, &img, 2);
        let mut tickets = Vec::new();
        let requests = (0..n)
            .map(|i| {
                let (t, tx) = Ticket::new(i as u64);
                tickets.push(t);
                ResizeRequest {
                    id: i as u64,
                    key,
                    image: img.clone(),
                    admitted: Instant::now(),
                    reply: tx,
                }
            })
            .collect();
        (Batch { key, requests }, tickets)
    }

    #[test]
    fn executes_and_replies() {
        let router = Router::new(&manifest(), TilePolicy::PortableFallback);
        let backend = MockEngine::new();
        let stats = ServingStats::new();
        let (batch, tickets) = make_batch(3);
        run_batch(batch, &router, &backend, &stats);
        for t in tickets {
            let out = t.wait().unwrap();
            assert_eq!(out.width(), 32);
        }
        assert_eq!(stats.completed.get(), 3);
        assert_eq!(stats.batches.get(), 1);
        assert_eq!(stats.mean_batch(), 3.0);
    }

    #[test]
    fn splits_oversize_groups() {
        let router = Router::new(&manifest(), TilePolicy::PortableFallback);
        let backend = MockEngine::new();
        let stats = ServingStats::new();
        let (batch, tickets) = make_batch(10); // max artifact batch = 4
        run_batch(batch, &router, &backend, &stats);
        for t in tickets {
            t.wait().unwrap();
        }
        assert_eq!(stats.completed.get(), 10);
        assert_eq!(stats.batches.get(), 3); // 4 + 4 + 2
    }

    #[test]
    fn backend_failure_propagates() {
        let router = Router::new(&manifest(), TilePolicy::PortableFallback);
        let backend = MockEngine::failing_every(1); // every batch fails
        let stats = ServingStats::new();
        let (batch, tickets) = make_batch(2);
        run_batch(batch, &router, &backend, &stats);
        for t in tickets {
            assert!(t.wait().is_err());
        }
        assert_eq!(stats.failed.get(), 2);
        assert_eq!(stats.completed.get(), 0);
    }

    #[test]
    fn unroutable_key_fails_cleanly() {
        let router = Router::new(&manifest(), TilePolicy::PortableFallback);
        let backend = MockEngine::new();
        let stats = ServingStats::new();
        let img = generate::gradient(8, 8); // no 8x8 artifact
        let key = RequestKey::of(Interpolator::Bilinear, &img, 2);
        let (t, tx) = Ticket::new(0);
        let batch = Batch {
            key,
            requests: vec![ResizeRequest {
                id: 0,
                key,
                image: img,
                admitted: Instant::now(),
                reply: tx,
            }],
        };
        run_batch(batch, &router, &backend, &stats);
        assert!(t.wait().is_err());
        assert_eq!(stats.failed.get(), 1);
    }
}
