//! Serving metrics: log-bucketed latency histograms and monotonic
//! counters, shared by the coordinator's stats and the bench harness.

pub mod histogram;

pub use histogram::Histogram;

use std::sync::atomic::{AtomicU64, Ordering};

/// A monotonically increasing counter, safe to share across threads.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }
    pub fn inc(&self) {
        self.add(1);
    }
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    /// Undo a previous `add` (e.g. an optimistic admission count rolled
    /// back when the enqueue fails). Callers must have added `n` first.
    pub fn sub(&self, n: u64) {
        self.0.fetch_sub(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
    /// Reset to zero (e.g. after a warmup phase).
    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn counter_concurrent() {
        let c = Arc::new(Counter::new());
        let mut handles = Vec::new();
        for _ in 0..8 {
            let c = Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    c.inc();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 8000);
    }

    #[test]
    fn counter_sub_rolls_back_adds() {
        let c = Counter::new();
        c.add(3);
        c.sub(1);
        assert_eq!(c.get(), 2);
    }
}
