//! A log-bucketed latency histogram (HdrHistogram-style, simplified).
//!
//! Buckets are geometric: bucket i covers `[base^i, base^(i+1))`
//! microseconds with base 1.2 — ~2% relative error, 128 buckets spanning
//! 1 µs to ~10 minutes. Recording is lock-free (atomic per-bucket adds),
//! so worker threads record directly into a shared histogram.

use std::sync::atomic::{AtomicU64, Ordering};

const N_BUCKETS: usize = 128;
const BASE: f64 = 1.2;

/// Lock-free latency histogram over microsecond values.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    fn bucket_of(us: f64) -> usize {
        if us <= 1.0 {
            return 0;
        }
        let i = us.ln() / BASE.ln();
        (i as usize).min(N_BUCKETS - 1)
    }

    /// Lower edge of bucket `i` in µs.
    fn bucket_lo(i: usize) -> f64 {
        BASE.powi(i as i32)
    }

    /// Record one latency in microseconds.
    pub fn record_us(&self, us: f64) {
        let us = us.max(0.0);
        self.buckets[Self::bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us as u64, Ordering::Relaxed);
        self.max_us.fetch_max(us as u64, Ordering::Relaxed);
    }

    /// Record a `std::time::Duration`.
    pub fn record(&self, d: std::time::Duration) {
        self.record_us(d.as_secs_f64() * 1e6);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency in µs (0 when empty).
    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    pub fn max_us(&self) -> f64 {
        self.max_us.load(Ordering::Relaxed) as f64
    }

    /// Approximate percentile in µs (`p` in [0,100]); 0 when empty.
    /// Error is bounded by the bucket width (~20%... the bucket's lower
    /// edge is reported, biasing slightly low but consistently).
    pub fn percentile_us(&self, p: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let target = ((p / 100.0) * n as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Self::bucket_lo(i);
            }
        }
        self.max_us()
    }

    /// Add every sample of `other` into this histogram (bucket-wise).
    /// Used to aggregate per-device serving histograms into fleet totals.
    pub fn merge_from(&self, other: &Histogram) {
        for (mine, theirs) in self.buckets.iter().zip(&other.buckets) {
            let n = theirs.load(Ordering::Relaxed);
            if n > 0 {
                mine.fetch_add(n, Ordering::Relaxed);
            }
        }
        self.count
            .fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum_us
            .fetch_add(other.sum_us.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max_us
            .fetch_max(other.max_us.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Reset all buckets and counters (e.g. after a warmup phase).
    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum_us.store(0, Ordering::Relaxed);
        self.max_us.store(0, Ordering::Relaxed);
    }

    /// A one-line text summary: `n=…, mean=…, p50=…, p99=…, max=… (µs)`.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.0}us p50={:.0}us p90={:.0}us p99={:.0}us max={:.0}us",
            self.count(),
            self.mean_us(),
            self.percentile_us(50.0),
            self.percentile_us(90.0),
            self.percentile_us(99.0),
            self.max_us()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean_us(), 0.0);
        assert_eq!(h.percentile_us(99.0), 0.0);
    }

    #[test]
    fn mean_and_max() {
        let h = Histogram::new();
        for v in [100.0, 200.0, 300.0] {
            h.record_us(v);
        }
        assert_eq!(h.count(), 3);
        assert!((h.mean_us() - 200.0).abs() < 1.0);
        assert_eq!(h.max_us(), 300.0);
    }

    #[test]
    fn percentile_monotone_and_bounded() {
        let h = Histogram::new();
        for i in 1..=1000 {
            h.record_us(i as f64);
        }
        let p50 = h.percentile_us(50.0);
        let p90 = h.percentile_us(90.0);
        let p99 = h.percentile_us(99.0);
        assert!(p50 <= p90 && p90 <= p99);
        // within bucket error of the true values
        assert!((400.0..600.0).contains(&p50), "p50={p50}");
        assert!((700.0..1100.0).contains(&p99), "p99={p99}");
    }

    #[test]
    fn concurrent_recording() {
        use std::sync::Arc;
        let h = Arc::new(Histogram::new());
        let mut handles = Vec::new();
        for t in 0..4 {
            let h = Arc::clone(&h);
            handles.push(std::thread::spawn(move || {
                for i in 0..1000 {
                    h.record_us((t * 1000 + i) as f64);
                }
            }));
        }
        for hd in handles {
            hd.join().unwrap();
        }
        assert_eq!(h.count(), 4000);
    }

    #[test]
    fn merge_combines_counts_and_max() {
        let a = Histogram::new();
        let b = Histogram::new();
        for v in [10.0, 20.0] {
            a.record_us(v);
        }
        for v in [30.0, 4000.0] {
            b.record_us(v);
        }
        a.merge_from(&b);
        assert_eq!(a.count(), 4);
        assert_eq!(a.max_us(), 4000.0);
        assert!((a.mean_us() - (10.0 + 20.0 + 30.0 + 4000.0) / 4.0).abs() < 1.0);
        // b unchanged
        assert_eq!(b.count(), 2);
    }

    #[test]
    fn tiny_values_land_in_first_bucket() {
        let h = Histogram::new();
        h.record_us(0.0);
        h.record_us(0.5);
        assert_eq!(h.count(), 2);
        assert!(h.percentile_us(100.0) <= BASE);
    }
}
