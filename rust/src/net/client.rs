//! The remote caller's side of the wire protocol.
//!
//! [`FleetClient`] is a blocking client whose surface mirrors the
//! in-process API: `submit(...)?.wait()?` on the data plane, and the
//! full [`FleetController`](crate::coordinator::FleetController) verb
//! set on the control plane. One client = one connection; the client is
//! `Clone` (clones share the connection) and **pipelines** its calls:
//! many requests may be outstanding at once, a background reader thread
//! demultiplexes responses by frame id to per-call waiters, and a slow
//! `wait` on one thread never blocks a `topology` on another.
//!
//! On connect the client runs the `hello` exchange (see
//! [`protocol`](super::protocol)): against a v2 server the session is
//! pinned to protocol v2 and images travel as length-prefixed binary
//! blocks; a pre-v2 server rejects the unknown verb on its id-0 error
//! channel and the client silently falls back to v1 JSON-array frames.
//! Set [`NetClientConfig::payload_encoding`] to
//! [`PayloadEncoding::Json`] to skip negotiation and force v1.
//!
//! Errors stay typed end to end: a remote
//! [`SubmitError`](crate::coordinator::SubmitError) comes back as
//! [`ClientError::Submit`] carrying the same variant the in-process
//! caller would have matched on.
//!
//! A response timeout or transport failure kills the current connection
//! *generation*: its in-flight calls fail with typed transport errors,
//! and the next call **automatically redials** with jittered
//! exponential backoff (budget [`NetClientConfig::reconnect_max_tries`]
//! attempts per call). Redialing is unconditional before anything hits
//! the wire; once a frame may have reached the server, only replay-safe
//! verbs (`topology`, `stats`, `autoscaler`) retry — a submit or
//! control mutation surfaces the failure instead of risking a duplicate
//! side effect. [`FleetClient::reconnect`] remains for callers that
//! want to force a fresh dial eagerly.

use super::protocol::{
    self, AutoscalerDesc, PayloadEncoding, ProtocolError, RequestFrame, ResponseFrame,
    TopologyDesc, Verb, WireError, WireStats, PROTOCOL_V2, PROTOCOL_VERSION,
};
use super::server::ListenAddr;
use crate::autotuner::TuningOutcome;
use crate::codec::json::Json;
use crate::coordinator::{AutoscalerUpdate, DrainMode, Request, SubmitError, TilePolicy};
use crate::image::Image;
use crate::tiling::TileDim;
use crate::util::Pcg32;
use std::collections::HashMap;
use std::fmt;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard};
use std::thread;
use std::time::{Duration, SystemTime};

/// How often the demultiplexer reader wakes from a quiet socket to
/// check whether its generation has been put down.
const READER_TICK: Duration = Duration::from_millis(100);

/// Client-side knobs; defaults match
/// [`NetConfig`](crate::config::NetConfig).
#[derive(Debug, Clone)]
pub struct NetClientConfig {
    /// TCP connect timeout.
    pub connect_timeout: Duration,
    /// How long one call may wait for its response before the
    /// connection is declared dead. Must exceed the server's per-call
    /// `wait` cap (5 s).
    pub response_timeout: Duration,
    /// Per-line byte cap for responses; binary payload blocks are held
    /// to the same budget.
    pub max_line_bytes: usize,
    /// `timeout_ms` sent with each remote `wait` poll.
    pub wait_poll: Duration,
    /// Most calls allowed in flight on the connection at once; callers
    /// past the cap block until a response frees a slot.
    pub max_inflight: usize,
    /// Base delay of the jittered exponential backoff between automatic
    /// redial attempts. Zero disables the sleep (retries stay bounded
    /// by [`reconnect_max_tries`](Self::reconnect_max_tries)).
    pub reconnect_backoff: Duration,
    /// Attempt budget per call: how many times one call may dial (or
    /// redial) before giving up with a transport error.
    pub reconnect_max_tries: u32,
    /// Wire encoding for image payloads. [`PayloadEncoding::Binary`]
    /// negotiates protocol v2 on connect and falls back to v1 against
    /// an old server; [`PayloadEncoding::Json`] forces v1.
    pub payload_encoding: PayloadEncoding,
}

impl Default for NetClientConfig {
    fn default() -> NetClientConfig {
        NetClientConfig {
            connect_timeout: Duration::from_secs(2),
            response_timeout: Duration::from_secs(10),
            max_line_bytes: protocol::DEFAULT_MAX_LINE_BYTES,
            wait_poll: Duration::from_secs(2),
            max_inflight: 32,
            reconnect_backoff: Duration::from_millis(50),
            reconnect_max_tries: 3,
            payload_encoding: PayloadEncoding::Binary,
        }
    }
}

/// Why a remote call failed.
#[derive(Debug)]
pub enum ClientError {
    /// The fleet refused the submit — same typed backpressure as
    /// in-process.
    Submit(SubmitError),
    /// The server returned a non-submit error frame (not-found, failed,
    /// internal, ...).
    Remote(WireError),
    /// This end could not decode what the server sent.
    Protocol(ProtocolError),
    /// The connection itself failed.
    Transport(String),
}

impl ClientError {
    /// The typed [`SubmitError`], when this error is one.
    pub fn submit_error(&self) -> Option<SubmitError> {
        match self {
            ClientError::Submit(e) => Some(*e),
            _ => None,
        }
    }
}

impl fmt::Display for ClientError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ClientError::Submit(e) => write!(f, "fleet refused submit: {e}"),
            ClientError::Remote(e) => write!(f, "remote error: {e}"),
            ClientError::Protocol(e) => write!(f, "protocol error: {e}"),
            ClientError::Transport(m) => write!(f, "transport error: {m}"),
        }
    }
}

impl std::error::Error for ClientError {}

/// Transport counters for one [`FleetClient`], cumulative across
/// reconnects. The byte counters cover request and response frames
/// (header line + binary block); the one-time `hello` exchange is not
/// counted.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WireMetrics {
    /// Total request bytes written to the socket.
    pub bytes_sent: u64,
    /// Total response bytes read from the socket.
    pub bytes_received: u64,
    /// How many times a fresh connection replaced a dead one (the
    /// initial dial is not a reconnect).
    pub reconnects: u64,
    /// Whether the *current* session negotiated protocol v2 (false when
    /// disconnected).
    pub v2_session: bool,
}

enum NetStream {
    Tcp(TcpStream),
    Unix(UnixStream),
}

impl NetStream {
    fn connect(addr: &ListenAddr, cfg: &NetClientConfig) -> Result<NetStream, ClientError> {
        match addr {
            ListenAddr::Tcp(a) => {
                let sa = a
                    .to_socket_addrs()
                    .map_err(|e| ClientError::Transport(format!("resolving {a}: {e}")))?
                    .next()
                    .ok_or_else(|| {
                        ClientError::Transport(format!("{a} resolved to no address"))
                    })?;
                let s = TcpStream::connect_timeout(&sa, cfg.connect_timeout)
                    .map_err(|e| ClientError::Transport(format!("connecting {a}: {e}")))?;
                s.set_nodelay(true).ok();
                Ok(NetStream::Tcp(s))
            }
            ListenAddr::Unix(p) => {
                let s = UnixStream::connect(p).map_err(|e| {
                    ClientError::Transport(format!("connecting {}: {e}", p.display()))
                })?;
                Ok(NetStream::Unix(s))
            }
        }
    }

    fn try_clone(&self) -> std::io::Result<NetStream> {
        match self {
            NetStream::Tcp(s) => s.try_clone().map(NetStream::Tcp),
            NetStream::Unix(s) => s.try_clone().map(NetStream::Unix),
        }
    }

    fn set_read_timeout(&self, t: Duration) -> std::io::Result<()> {
        match self {
            NetStream::Tcp(s) => s.set_read_timeout(Some(t)),
            NetStream::Unix(s) => s.set_read_timeout(Some(t)),
        }
    }

    fn shutdown_both(&self) {
        let _ = match self {
            NetStream::Tcp(s) => s.shutdown(std::net::Shutdown::Both),
            NetStream::Unix(s) => s.shutdown(std::net::Shutdown::Both),
        };
    }
}

impl Read for NetStream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            NetStream::Tcp(s) => s.read(buf),
            NetStream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for NetStream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            NetStream::Tcp(s) => s.write(buf),
            NetStream::Unix(s) => s.write(buf),
        }
    }
    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            NetStream::Tcp(s) => s.flush(),
            NetStream::Unix(s) => s.flush(),
        }
    }
}

/// A demultiplexed response: the frame plus its binary block, if the
/// header announced one.
type Reply = (ResponseFrame, Option<Vec<u8>>);

/// One connection *generation*: a dialed socket, the protocol version
/// its `hello` exchange pinned, and the table of calls awaiting
/// responses on it. Generations are immutable once dead — a redial
/// builds a new one, so late frames from an old socket can never be
/// routed to new callers.
struct Generation {
    /// Protocol version the session speaks (1 or 2).
    version: u64,
    /// Write half. Callers serialize frame writes through this lock
    /// only — reads happen on the reader thread.
    writer: Mutex<NetStream>,
    /// Spare handle used only to shut the socket down; shutdown takes
    /// `&self`, so a killer never waits on the writer lock.
    socket: NetStream,
    state: Mutex<GenState>,
    /// Signalled when a waiter slot frees up or the generation dies.
    room: Condvar,
}

struct GenState {
    /// Why this generation can no longer be trusted, once set.
    dead: Option<String>,
    /// In-flight calls by frame id. `len()` is the inflight count; the
    /// map doubles as the admission gate for `max_inflight`.
    waiters: HashMap<u64, mpsc::Sender<Reply>>,
}

impl Generation {
    fn lock_state(&self) -> MutexGuard<'_, GenState> {
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn is_dead(&self) -> bool {
        self.lock_state().dead.is_some()
    }

    fn dead_reason(&self) -> String {
        self.lock_state()
            .dead
            .clone()
            .unwrap_or_else(|| "connection replaced".into())
    }

    /// Put the generation down: record why, fail every pending call
    /// (dropping a waiter's sender wakes its `recv_timeout` with
    /// `Disconnected`), and tear the socket down so the reader thread
    /// and the server both notice.
    fn kill(&self, why: &str) {
        {
            let mut st = self.lock_state();
            if st.dead.is_none() {
                st.dead = Some(why.to_string());
            }
            st.waiters.clear();
        }
        self.room.notify_all();
        self.socket.shutdown_both();
    }

    /// Hand a response to whichever call registered its id. A missing
    /// waiter means the caller already gave up; the frame is dropped
    /// without disturbing the stream.
    fn route(&self, resp: ResponseFrame, blob: Option<Vec<u8>>) {
        let tx = self.lock_state().waiters.remove(&resp.id);
        if let Some(tx) = tx {
            let _ = tx.send((resp, blob));
        }
        self.room.notify_all();
    }
}

/// Byte/reconnect counters shared with reader threads. Kept in its own
/// `Arc` (not inside [`ClientShared`]) so a parked reader never keeps
/// the client — and therefore itself — alive.
#[derive(Default)]
struct Metrics {
    bytes_sent: AtomicU64,
    bytes_received: AtomicU64,
    reconnects: AtomicU64,
}

struct ClientShared {
    cfg: NetClientConfig,
    addr: ListenAddr,
    /// Frame ids count up monotonically across generations, so frames
    /// from two connection generations can never be confused.
    next_id: AtomicU64,
    current: Mutex<Option<Arc<Generation>>>,
    /// Serializes redials so a burst of failing calls dials once, not
    /// once each.
    dial_lock: Mutex<()>,
    jitter: Mutex<Pcg32>,
    metrics: Arc<Metrics>,
}

impl Drop for ClientShared {
    fn drop(&mut self) {
        let gen = match self.current.get_mut() {
            Ok(cur) => cur.take(),
            Err(poisoned) => poisoned.into_inner().take(),
        };
        if let Some(g) = gen {
            g.kill("client dropped");
        }
    }
}

/// The demultiplexer: reads frames off one generation's socket and
/// routes them to per-call waiters by id. Any framing failure — or an
/// id-0 error frame, the server's out-of-band channel for framing
/// complaints — kills the generation, because the stream can no longer
/// be trusted to answer anyone.
fn reader_loop(
    gen: &Generation,
    reader: &mut BufReader<NetStream>,
    max_line_bytes: usize,
    metrics: &Metrics,
) {
    loop {
        if gen.is_dead() {
            return;
        }
        let line = match protocol::read_frame_line(reader, max_line_bytes) {
            Ok(Some(l)) => l,
            Ok(None) => {
                gen.kill("server closed the connection");
                return;
            }
            // Quiet socket: per-call deadlines live with the callers,
            // the reader just checks for shutdown and keeps listening.
            Err(ProtocolError::Timeout) => continue,
            Err(e) => {
                gen.kill(&e.to_string());
                return;
            }
        };
        let header = match Json::parse(line.trim_end_matches(['\r', '\n'])) {
            Ok(j) => j,
            Err(e) => {
                gen.kill(&format!("malformed response frame: {e}"));
                return;
            }
        };
        let extra = match protocol::frame_extra_bytes(&header) {
            Ok(n) => n,
            Err(e) => {
                gen.kill(&e.to_string());
                return;
            }
        };
        let blob = if extra > 0 {
            match protocol::read_payload(reader, extra, max_line_bytes) {
                Ok(b) => Some(b),
                Err(e) => {
                    gen.kill(&e.to_string());
                    return;
                }
            }
        } else {
            None
        };
        metrics
            .bytes_received
            .fetch_add((line.len() + extra + 1) as u64, Ordering::Relaxed);
        let resp = match ResponseFrame::from_json(&header) {
            Ok(r) => r,
            Err(e) => {
                gen.kill(&e.to_string());
                return;
            }
        };
        if resp.id == 0 {
            let why = match &resp.body {
                Err(e) => format!("server reported: {e}"),
                Ok(_) => "server sent an id-0 response".to_string(),
            };
            gen.kill(&why);
            return;
        }
        gen.route(resp, blob);
    }
}

/// Run the client half of the `hello` exchange on a fresh connection;
/// returns whether the session speaks v2. A pre-v2 server answers the
/// unknown verb with an error frame and keeps the connection usable —
/// that is the v1 fallback, not a failure.
fn negotiate_session<R: BufRead, W: Write>(
    reader: &mut R,
    writer: &mut W,
    id: u64,
    max_line_bytes: usize,
) -> Result<bool, ClientError> {
    let frame = RequestFrame::new(id, Verb::Hello, protocol::encode_hello(PROTOCOL_V2));
    writer
        .write_all(&frame.to_wire(PROTOCOL_VERSION, None))
        .and_then(|_| writer.flush())
        .map_err(|e| ClientError::Transport(format!("hello send failed: {e}")))?;
    let line = match protocol::read_frame_line(reader, max_line_bytes) {
        Ok(Some(l)) => l,
        Ok(None) => {
            return Err(ClientError::Transport(
                "server closed the connection during hello".into(),
            ))
        }
        Err(ProtocolError::Timeout) => {
            return Err(ClientError::Transport("no response to hello".into()))
        }
        Err(e) => return Err(ClientError::Protocol(e)),
    };
    let resp = ResponseFrame::parse(&line).map_err(ClientError::Protocol)?;
    match resp.body {
        Ok(body) if resp.id == id => {
            let version = body
                .get("version")
                .and_then(Json::as_u64)
                .unwrap_or(PROTOCOL_VERSION);
            Ok(version >= PROTOCOL_V2)
        }
        Ok(_) => Err(ClientError::Transport(format!(
            "hello answered with id {} instead of {id}",
            resp.id
        ))),
        // An old server reports `unknown verb 'hello'` (on its id-0
        // error channel) and keeps the line open: speak v1 to it.
        Err(_) => Ok(false),
    }
}

/// A blocking remote handle to a [`Fleet`](crate::coordinator::Fleet)
/// served by a [`NetServer`](super::NetServer). Cheap to clone; clones
/// share one pipelined connection, and each call gets its own response
/// slot, so clones on different threads proceed concurrently.
#[derive(Clone)]
pub struct FleetClient {
    shared: Arc<ClientShared>,
}

impl FleetClient {
    /// Connect with default [`NetClientConfig`].
    pub fn connect(addr: &ListenAddr) -> Result<FleetClient, ClientError> {
        FleetClient::connect_with(addr, NetClientConfig::default())
    }

    /// Connect with explicit knobs. Dials (and runs the `hello`
    /// exchange, unless `payload_encoding` is `Json`) eagerly, so an
    /// unreachable server fails here rather than on the first call.
    pub fn connect_with(
        addr: &ListenAddr,
        cfg: NetClientConfig,
    ) -> Result<FleetClient, ClientError> {
        let seed = SystemTime::now()
            .duration_since(SystemTime::UNIX_EPOCH)
            .map(|d| u64::from(d.subsec_nanos()) ^ d.as_secs())
            .unwrap_or(0x9e37_79b9_7f4a_7c15);
        let client = FleetClient {
            shared: Arc::new(ClientShared {
                cfg,
                addr: addr.clone(),
                next_id: AtomicU64::new(1),
                current: Mutex::new(None),
                dial_lock: Mutex::new(()),
                jitter: Mutex::new(Pcg32::seeded(seed)),
                metrics: Arc::new(Metrics::default()),
            }),
        };
        client.ensure_gen()?;
        Ok(client)
    }

    /// The address this client connected to.
    pub fn addr(&self) -> &ListenAddr {
        &self.shared.addr
    }

    /// Cumulative transport counters (bytes on the wire, reconnects)
    /// plus whether the current session speaks protocol v2.
    pub fn wire_metrics(&self) -> WireMetrics {
        let m = &self.shared.metrics;
        WireMetrics {
            bytes_sent: m.bytes_sent.load(Ordering::Relaxed),
            bytes_received: m.bytes_received.load(Ordering::Relaxed),
            reconnects: m.reconnects.load(Ordering::Relaxed),
            v2_session: self
                .live_gen()
                .map(|g| g.version >= PROTOCOL_V2)
                .unwrap_or(false),
        }
    }

    /// Whether the client is currently disconnected (the last
    /// connection died and nothing has redialed yet). Calls made in
    /// this state redial automatically; this is observability, not a
    /// gate.
    pub fn is_dead(&self) -> bool {
        self.live_gen().is_none()
    }

    /// Force a fresh dial now, replacing the current connection (live
    /// or dead) for all clones. Calls redial automatically on failure,
    /// so this is only needed to *eagerly* re-establish connectivity —
    /// e.g. a health prober that wants dial errors surfaced on its own
    /// schedule. Server-side tickets from the old connection are
    /// settled by the server when it notices the close.
    pub fn reconnect(&self) -> Result<(), ClientError> {
        {
            let cur = self
                .shared
                .current
                .lock()
                .unwrap_or_else(|p| p.into_inner());
            if let Some(g) = cur.as_ref() {
                g.kill("explicitly reconnected");
            }
        }
        self.ensure_gen().map(|_| ())
    }

    // ------------------------------------------- connection plumbing --

    fn live_gen(&self) -> Option<Arc<Generation>> {
        let cur = self
            .shared
            .current
            .lock()
            .unwrap_or_else(|p| p.into_inner());
        cur.as_ref().filter(|g| !g.is_dead()).map(Arc::clone)
    }

    /// The current generation, dialing a fresh one if the last died.
    /// One dialer at a time: racers park on `dial_lock` and adopt the
    /// winner's connection.
    fn ensure_gen(&self) -> Result<Arc<Generation>, ClientError> {
        if let Some(g) = self.live_gen() {
            return Ok(g);
        }
        let _dialing = self
            .shared
            .dial_lock
            .lock()
            .unwrap_or_else(|p| p.into_inner());
        if let Some(g) = self.live_gen() {
            return Ok(g);
        }
        let gen = self.dial()?;
        let mut cur = self
            .shared
            .current
            .lock()
            .unwrap_or_else(|p| p.into_inner());
        if cur.is_some() {
            self.shared.metrics.reconnects.fetch_add(1, Ordering::Relaxed);
        }
        *cur = Some(Arc::clone(&gen));
        Ok(gen)
    }

    fn dial(&self) -> Result<Arc<Generation>, ClientError> {
        let cfg = &self.shared.cfg;
        let io_err = |e: std::io::Error| ClientError::Transport(e.to_string());
        let stream = NetStream::connect(&self.shared.addr, cfg)?;
        stream.set_read_timeout(cfg.response_timeout).map_err(io_err)?;
        let mut reader = BufReader::new(stream.try_clone().map_err(io_err)?);
        let socket = stream.try_clone().map_err(io_err)?;
        let mut writer = stream;
        let version = match cfg.payload_encoding {
            PayloadEncoding::Json => PROTOCOL_VERSION,
            PayloadEncoding::Binary => {
                let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
                if negotiate_session(&mut reader, &mut writer, id, cfg.max_line_bytes)? {
                    PROTOCOL_V2
                } else {
                    PROTOCOL_VERSION
                }
            }
        };
        socket.set_read_timeout(READER_TICK).map_err(io_err)?;
        let gen = Arc::new(Generation {
            version,
            writer: Mutex::new(writer),
            socket,
            state: Mutex::new(GenState {
                dead: None,
                waiters: HashMap::new(),
            }),
            room: Condvar::new(),
        });
        let thread_gen = Arc::clone(&gen);
        let thread_metrics = Arc::clone(&self.shared.metrics);
        let max_line_bytes = cfg.max_line_bytes;
        let spawned = thread::Builder::new()
            .name("net-client-read".into())
            .spawn(move || reader_loop(&thread_gen, &mut reader, max_line_bytes, &thread_metrics));
        if let Err(e) = spawned {
            gen.kill("reader thread spawn failed");
            return Err(ClientError::Transport(format!("spawning reader: {e}")));
        }
        Ok(gen)
    }

    /// Claim an in-flight slot and register a response waiter under
    /// `id`. Blocks (bounded by the response timeout) while the
    /// connection is at `max_inflight`.
    fn register(&self, gen: &Generation, id: u64) -> Result<mpsc::Receiver<Reply>, String> {
        let cap = self.shared.cfg.max_inflight.max(1);
        let mut st = gen.lock_state();
        loop {
            if let Some(why) = &st.dead {
                return Err(format!(
                    "connection to {} is dead ({why})",
                    self.shared.addr
                ));
            }
            if st.waiters.len() < cap {
                break;
            }
            let (guard, waited) = match gen
                .room
                .wait_timeout(st, self.shared.cfg.response_timeout)
            {
                Ok(r) => r,
                Err(poisoned) => poisoned.into_inner(),
            };
            st = guard;
            if waited.timed_out() && st.waiters.len() >= cap && st.dead.is_none() {
                return Err(format!(
                    "no in-flight slot freed within {:?} ({} calls outstanding)",
                    self.shared.cfg.response_timeout,
                    st.waiters.len()
                ));
            }
        }
        let (tx, rx) = mpsc::channel();
        st.waiters.insert(id, tx);
        Ok(rx)
    }

    /// One pipelined request/response exchange, with automatic
    /// redial-with-backoff. `build` maps the session's protocol version
    /// to the payload (and optional binary block), so a submit can
    /// choose binary pixels on v2 and JSON on v1 per attempt.
    ///
    /// Failures before the frame is written retry for any verb —
    /// nothing reached the server. Once the frame may have been
    /// received, only replay-safe verbs retry; everything else surfaces
    /// a typed transport error so the caller decides about duplicated
    /// side effects.
    fn call_versioned<F>(&self, verb: Verb, build: F) -> Result<(Json, Option<Vec<u8>>), ClientError>
    where
        F: Fn(u64) -> (Json, Option<Vec<u8>>),
    {
        let replayable = matches!(verb, Verb::Topology | Verb::Stats | Verb::Autoscaler);
        let budget = self.shared.cfg.reconnect_max_tries.max(1);
        let mut attempt = 0u32;
        loop {
            attempt += 1;
            let gen = match self.ensure_gen() {
                Ok(g) => g,
                Err(e) => {
                    if attempt >= budget {
                        return Err(e);
                    }
                    self.backoff_sleep(attempt);
                    continue;
                }
            };
            let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
            let rx = match self.register(&gen, id) {
                Ok(rx) => rx,
                // The generation died (or jammed) under us; nothing was
                // written, so any verb may redial.
                Err(why) => {
                    if attempt >= budget {
                        return Err(ClientError::Transport(why));
                    }
                    self.backoff_sleep(attempt);
                    continue;
                }
            };
            let (payload, blob) = build(gen.version);
            let wire = RequestFrame::new(id, verb, payload).to_wire(gen.version, blob.as_deref());
            let sent = {
                let mut w = gen.writer.lock().unwrap_or_else(|p| p.into_inner());
                w.write_all(&wire).and_then(|_| w.flush())
            };
            if let Err(e) = sent {
                // A failed write may still have partially reached the
                // server, so from here on only replay-safe verbs retry.
                gen.kill(&format!("send failed: {e}"));
                if replayable && attempt < budget {
                    self.backoff_sleep(attempt);
                    continue;
                }
                return Err(ClientError::Transport(format!("send failed: {e}")));
            }
            self.shared
                .metrics
                .bytes_sent
                .fetch_add(wire.len() as u64, Ordering::Relaxed);
            match rx.recv_timeout(self.shared.cfg.response_timeout) {
                Ok((resp, resp_blob)) => {
                    return match resp.body {
                        Ok(body) => Ok((body, resp_blob)),
                        Err(wire_err) => match wire_err.to_submit() {
                            Some(se) => Err(ClientError::Submit(se)),
                            None => Err(ClientError::Remote(wire_err)),
                        },
                    };
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    // The server went quiet mid-call; the connection
                    // can no longer be trusted to answer anyone.
                    let why =
                        format!("no response within {:?}", self.shared.cfg.response_timeout);
                    gen.kill(&why);
                    if replayable && attempt < budget {
                        self.backoff_sleep(attempt);
                        continue;
                    }
                    return Err(ClientError::Transport(why));
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    let why = gen.dead_reason();
                    if replayable && attempt < budget {
                        self.backoff_sleep(attempt);
                        continue;
                    }
                    return Err(ClientError::Transport(format!(
                        "connection to {} died mid-call ({why})",
                        self.shared.addr
                    )));
                }
            }
        }
    }

    fn call(&self, verb: Verb, payload: Json) -> Result<Json, ClientError> {
        self.call_versioned(verb, |_| (payload.clone(), None))
            .map(|(body, _)| body)
    }

    /// Jittered exponential backoff before redial attempt
    /// `attempt + 1`: a uniform draw from [1/2, 1] of
    /// `reconnect_backoff * 2^(attempt-1)`, so synchronized clients fan
    /// out instead of stampeding a recovering server.
    fn backoff_sleep(&self, attempt: u32) {
        let base = self.shared.cfg.reconnect_backoff;
        if base.is_zero() {
            return;
        }
        let step = base * 2u32.saturating_pow(attempt.saturating_sub(1)).min(64);
        let frac = {
            let mut rng = self.shared.jitter.lock().unwrap_or_else(|p| p.into_inner());
            0.5 + 0.5 * rng.f64()
        };
        thread::sleep(step.mul_f64(frac));
    }

    // ------------------------------------------------- data plane --

    /// Submit a request to the remote fleet. Mirrors
    /// [`Fleet::submit`](crate::coordinator::Fleet::submit): a refusal
    /// is a typed [`SubmitError`] via [`ClientError::Submit`]. On a v2
    /// session the pixels travel as a binary block after the header
    /// line; on v1 as a JSON array.
    pub fn submit(&self, req: &Request) -> Result<RemoteTicket, ClientError> {
        let (body, _) = self.call_versioned(Verb::Submit, |version| {
            if version >= PROTOCOL_V2 {
                let (payload, blob) = protocol::encode_submit_blob(req);
                (payload, Some(blob))
            } else {
                (protocol::encode_submit(req), None)
            }
        })?;
        let id = body
            .get("ticket")
            .and_then(Json::as_u64)
            .ok_or_else(|| bad_body("submit response missing 'ticket'"))?;
        Ok(RemoteTicket {
            id,
            device: body
                .get("device")
                .and_then(Json::as_str)
                .map(str::to_string),
            client: self.clone(),
        })
    }

    // ---------------------------------------------- control plane --

    /// Epoch-stamped remote topology snapshot.
    pub fn topology(&self) -> Result<TopologyDesc, ClientError> {
        let body = self.call(Verb::Topology, Json::obj())?;
        TopologyDesc::from_json(&body).map_err(ClientError::Protocol)
    }

    /// Current topology epoch.
    pub fn epoch(&self) -> Result<u64, ClientError> {
        Ok(self.topology()?.epoch)
    }

    /// Remote fleet-wide [`WireStats`].
    pub fn stats(&self) -> Result<WireStats, ClientError> {
        let body = self.call(Verb::Stats, Json::obj())?;
        WireStats::from_json(&body).map_err(ClientError::Protocol)
    }

    /// Add a registry device to the remote fleet; returns
    /// `(member id, new epoch)`.
    pub fn add_member(
        &self,
        device: &str,
        policy: &TilePolicy,
    ) -> Result<(u64, u64), ClientError> {
        let body = self.call(
            Verb::AddMember,
            Json::obj()
                .set("device", device)
                .set("policy", protocol::encode_policy(policy)),
        )?;
        let member = body
            .get("member")
            .and_then(Json::as_u64)
            .ok_or_else(|| bad_body("add_member response missing 'member'"))?;
        let epoch = body
            .get("epoch")
            .and_then(Json::as_u64)
            .ok_or_else(|| bad_body("add_member response missing 'epoch'"))?;
        Ok((member, epoch))
    }

    /// Remove a member; returns the new epoch.
    pub fn remove_member(&self, device: &str, mode: DrainMode) -> Result<u64, ClientError> {
        let body = self.call(
            Verb::RemoveMember,
            Json::obj()
                .set("device", device)
                .set("mode", protocol::drain_mode_name(mode)),
        )?;
        body.get("epoch")
            .and_then(Json::as_u64)
            .ok_or_else(|| bad_body("remove_member response missing 'epoch'"))
    }

    /// Stop admissions to a member without removing it; returns the new
    /// epoch.
    pub fn drain(&self, device: &str) -> Result<u64, ClientError> {
        let body = self.call(Verb::Drain, Json::obj().set("device", device))?;
        body.get("epoch")
            .and_then(Json::as_u64)
            .ok_or_else(|| bad_body("drain response missing 'epoch'"))
    }

    /// Hot-swap a member's tuned tile from a fresh outcome; returns the
    /// tile now in effect (None if the outcome had no tile for it).
    pub fn retune(
        &self,
        device: &str,
        outcome: &TuningOutcome,
    ) -> Result<Option<TileDim>, ClientError> {
        let body = self.call(
            Verb::Retune,
            Json::obj()
                .set("device", device)
                .set("outcome", outcome.to_json()),
        )?;
        match body.get("tile") {
            None | Some(Json::Null) => Ok(None),
            Some(t) => {
                let s = t
                    .as_str()
                    .ok_or_else(|| bad_body("retune response 'tile' must be a string"))?;
                s.parse::<TileDim>()
                    .map(Some)
                    .map_err(|e: String| bad_body(format!("retune response tile: {e}")))
            }
        }
    }

    /// Swap the remote scheduler by registry name.
    pub fn set_scheduler(&self, name: &str) -> Result<(), ClientError> {
        self.call(Verb::SetScheduler, Json::obj().set("name", name))?;
        Ok(())
    }

    /// Swap the remote admission policy by registry name.
    pub fn set_admission(&self, name: &str, timeout: Duration) -> Result<(), ClientError> {
        self.call(
            Verb::SetAdmission,
            Json::obj()
                .set("name", name)
                .set("timeout_ms", timeout.as_secs_f64() * 1e3),
        )?;
        Ok(())
    }

    /// Reconfigure remote work stealing.
    pub fn set_steal_config(&self, enabled: bool, threshold: usize) -> Result<(), ClientError> {
        self.call(
            Verb::SetStealConfig,
            Json::obj().set("enabled", enabled).set("threshold", threshold),
        )?;
        Ok(())
    }

    /// Snapshot the remote autoscaler's knobs and counters. A server
    /// running without one answers not-found ([`ClientError::Remote`]
    /// with kind `not-found`).
    pub fn autoscaler(&self) -> Result<AutoscalerDesc, ClientError> {
        let body = self.call(Verb::Autoscaler, Json::obj())?;
        AutoscalerDesc::from_json(&body).map_err(ClientError::Protocol)
    }

    /// Apply a partial [`AutoscalerUpdate`] to the remote autoscaler;
    /// returns the post-update state (no second round trip needed).
    /// An invalid resulting band is a remote error, not a dead
    /// connection.
    pub fn set_autoscaler(&self, update: &AutoscalerUpdate) -> Result<AutoscalerDesc, ClientError> {
        let body = self.call(
            Verb::SetAutoscaler,
            protocol::encode_autoscaler_update(update),
        )?;
        AutoscalerDesc::from_json(&body).map_err(ClientError::Protocol)
    }
}

fn bad_body(msg: impl Into<String>) -> ClientError {
    ClientError::Protocol(ProtocolError::Malformed(msg.into()))
}

/// The remote analogue of [`Ticket`](crate::coordinator::Ticket): a
/// stable server-side ticket id plus the connection to poll it on.
pub struct RemoteTicket {
    id: u64,
    device: Option<String>,
    client: FleetClient,
}

impl RemoteTicket {
    /// Server-side ticket id (stable across the wire).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// The device the scheduler picked at admission, when known.
    pub fn device_id(&self) -> Option<&str> {
        self.device.as_deref()
    }

    fn poll(&self, verb: Verb, budget: Option<Duration>) -> Result<Option<Image<f32>>, ClientError> {
        let payload = Json::obj().set("ticket", self.id);
        let payload = match budget {
            Some(b) => payload.set("timeout_ms", b.as_secs_f64() * 1e3),
            None => payload,
        };
        let (body, blob) = self
            .client
            .call_versioned(verb, |_| (payload.clone(), None))?;
        let done = body
            .get("done")
            .and_then(Json::as_bool)
            .ok_or_else(|| bad_body("wait response missing 'done'"))?;
        if !done {
            return Ok(None);
        }
        let img = body
            .get("image")
            .ok_or_else(|| bad_body("completed wait response missing 'image'"))?;
        protocol::decode_image_any(img, blob.as_deref())
            .map(Some)
            .map_err(ClientError::Protocol)
    }

    /// Block until the result arrives (looping bounded server-side
    /// polls), consuming the ticket — the remote mirror of
    /// [`Ticket::wait`](crate::coordinator::Ticket::wait).
    pub fn wait(self) -> Result<Image<f32>, ClientError> {
        loop {
            if let Some(img) = self.poll(Verb::Wait, Some(self.client.shared.cfg.wait_poll))? {
                return Ok(img);
            }
        }
    }

    /// One bounded wait; `Ok(None)` means not done yet (ticket stays
    /// valid).
    pub fn wait_timeout(&self, budget: Duration) -> Result<Option<Image<f32>>, ClientError> {
        self.poll(Verb::Wait, Some(budget))
    }

    /// Non-blocking poll; `Ok(None)` means not done yet.
    pub fn try_wait(&self) -> Result<Option<Image<f32>>, ClientError> {
        self.poll(Verb::TryWait, None)
    }

    /// Request cancellation. The ticket still resolves (as cancelled) —
    /// observe it via `wait`/`try_wait`.
    pub fn cancel(&self) -> Result<(), ClientError> {
        self.client.call(Verb::Cancel, Json::obj().set("ticket", self.id))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::protocol::WireErrorKind;
    use std::io::Cursor;

    fn hello_ok_reply(id: u64, version: u64) -> Vec<u8> {
        ResponseFrame::ok(id, Json::obj().set("version", version)).to_wire(PROTOCOL_VERSION, None)
    }

    #[test]
    fn negotiation_accepts_a_v2_server() {
        let mut reader = Cursor::new(hello_ok_reply(7, 2));
        let mut writer = Vec::new();
        assert!(negotiate_session(&mut reader, &mut writer, 7, 1 << 20).unwrap());
        let sent = String::from_utf8(writer).unwrap();
        assert!(sent.contains("\"verb\":\"hello\""), "sent: {sent}");
        assert!(sent.contains("\"max\":2"), "sent: {sent}");
    }

    #[test]
    fn negotiation_pins_v1_when_the_server_answers_v1() {
        let mut reader = Cursor::new(hello_ok_reply(1, 1));
        let mut writer = Vec::new();
        assert!(!negotiate_session(&mut reader, &mut writer, 1, 1 << 20).unwrap());
    }

    #[test]
    fn negotiation_falls_back_when_the_server_rejects_hello() {
        // A pre-v2 server answers the unknown verb on its id-0 error
        // channel and keeps the connection open — that pins v1.
        let reply = ResponseFrame::err(
            0,
            WireError::new(WireErrorKind::Protocol, "unknown verb 'hello'"),
        )
        .to_wire(PROTOCOL_VERSION, None);
        let mut reader = Cursor::new(reply);
        let mut writer = Vec::new();
        assert!(!negotiate_session(&mut reader, &mut writer, 3, 1 << 20).unwrap());
    }

    #[test]
    fn negotiation_fails_on_a_closed_stream() {
        let mut reader = Cursor::new(Vec::new());
        let mut writer = Vec::new();
        let err = negotiate_session(&mut reader, &mut writer, 1, 1 << 20).unwrap_err();
        assert!(matches!(err, ClientError::Transport(_)), "got {err}");
    }

    #[test]
    fn negotiation_rejects_a_desynced_ok() {
        // An ok response for some *other* id means the stream is not
        // answering our hello — that is a hard error, not a fallback.
        let mut reader = Cursor::new(hello_ok_reply(99, 2));
        let mut writer = Vec::new();
        let err = negotiate_session(&mut reader, &mut writer, 3, 1 << 20).unwrap_err();
        assert!(matches!(err, ClientError::Transport(_)), "got {err}");
    }
}
