//! The remote caller's side of the wire protocol.
//!
//! [`FleetClient`] is a blocking client whose surface mirrors the
//! in-process API: `submit(...)?.wait()?` on the data plane, and the
//! full [`FleetController`](crate::coordinator::FleetController) verb
//! set on the control plane. One client = one connection; the client is
//! `Clone` (clones share the connection) and keeps exactly one call
//! outstanding at a time, so responses always arrive in call order.
//!
//! Errors stay typed end to end: a remote
//! [`SubmitError`](crate::coordinator::SubmitError) comes back as
//! [`ClientError::Submit`] carrying the same variant the in-process
//! caller would have matched on.
//!
//! A response timeout (or any framing failure) **poisons** the shared
//! connection: the late response can no longer be told apart from the
//! next call's answer, so every subsequent call fails fast with a
//! "connection is dead" transport error until
//! [`FleetClient::reconnect`] dials a fresh connection in place.

use super::protocol::{
    self, AutoscalerDesc, ProtocolError, RequestFrame, ResponseFrame, TopologyDesc, Verb,
    WireError, WireStats,
};
use super::server::ListenAddr;
use crate::autotuner::TuningOutcome;
use crate::codec::json::Json;
use crate::coordinator::{AutoscalerUpdate, DrainMode, Request, SubmitError, TilePolicy};
use crate::image::Image;
use crate::tiling::TileDim;
use std::fmt;
use std::io::{BufReader, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::os::unix::net::UnixStream;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Client-side knobs; defaults match
/// [`NetConfig`](crate::config::NetConfig).
#[derive(Debug, Clone)]
pub struct NetClientConfig {
    /// TCP connect timeout.
    pub connect_timeout: Duration,
    /// How long one call may wait for its response before the
    /// connection is declared dead. Must exceed the server's per-call
    /// `wait` cap (5 s).
    pub response_timeout: Duration,
    /// Per-line byte cap for responses.
    pub max_line_bytes: usize,
    /// `timeout_ms` sent with each remote `wait` poll.
    pub wait_poll: Duration,
}

impl Default for NetClientConfig {
    fn default() -> NetClientConfig {
        NetClientConfig {
            connect_timeout: Duration::from_secs(2),
            response_timeout: Duration::from_secs(10),
            max_line_bytes: protocol::DEFAULT_MAX_LINE_BYTES,
            wait_poll: Duration::from_secs(2),
        }
    }
}

/// Why a remote call failed.
#[derive(Debug)]
pub enum ClientError {
    /// The fleet refused the submit — same typed backpressure as
    /// in-process.
    Submit(SubmitError),
    /// The server returned a non-submit error frame (not-found, failed,
    /// internal, ...).
    Remote(WireError),
    /// This end could not decode what the server sent.
    Protocol(ProtocolError),
    /// The connection itself failed.
    Transport(String),
}

impl ClientError {
    /// The typed [`SubmitError`], when this error is one.
    pub fn submit_error(&self) -> Option<SubmitError> {
        match self {
            ClientError::Submit(e) => Some(*e),
            _ => None,
        }
    }
}

impl fmt::Display for ClientError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ClientError::Submit(e) => write!(f, "fleet refused submit: {e}"),
            ClientError::Remote(e) => write!(f, "remote error: {e}"),
            ClientError::Protocol(e) => write!(f, "protocol error: {e}"),
            ClientError::Transport(m) => write!(f, "transport error: {m}"),
        }
    }
}

impl std::error::Error for ClientError {}

enum NetStream {
    Tcp(TcpStream),
    Unix(UnixStream),
}

impl NetStream {
    fn connect(addr: &ListenAddr, cfg: &NetClientConfig) -> Result<NetStream, ClientError> {
        match addr {
            ListenAddr::Tcp(a) => {
                let sa = a
                    .to_socket_addrs()
                    .map_err(|e| ClientError::Transport(format!("resolving {a}: {e}")))?
                    .next()
                    .ok_or_else(|| {
                        ClientError::Transport(format!("{a} resolved to no address"))
                    })?;
                let s = TcpStream::connect_timeout(&sa, cfg.connect_timeout)
                    .map_err(|e| ClientError::Transport(format!("connecting {a}: {e}")))?;
                s.set_nodelay(true).ok();
                Ok(NetStream::Tcp(s))
            }
            ListenAddr::Unix(p) => {
                let s = UnixStream::connect(p).map_err(|e| {
                    ClientError::Transport(format!("connecting {}: {e}", p.display()))
                })?;
                Ok(NetStream::Unix(s))
            }
        }
    }

    fn try_clone(&self) -> std::io::Result<NetStream> {
        match self {
            NetStream::Tcp(s) => s.try_clone().map(NetStream::Tcp),
            NetStream::Unix(s) => s.try_clone().map(NetStream::Unix),
        }
    }

    fn set_read_timeout(&self, t: Duration) -> std::io::Result<()> {
        match self {
            NetStream::Tcp(s) => s.set_read_timeout(Some(t)),
            NetStream::Unix(s) => s.set_read_timeout(Some(t)),
        }
    }

    fn shutdown_both(&self) {
        let _ = match self {
            NetStream::Tcp(s) => s.shutdown(std::net::Shutdown::Both),
            NetStream::Unix(s) => s.shutdown(std::net::Shutdown::Both),
        };
    }
}

impl Read for NetStream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            NetStream::Tcp(s) => s.read(buf),
            NetStream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for NetStream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            NetStream::Tcp(s) => s.write(buf),
            NetStream::Unix(s) => s.write(buf),
        }
    }
    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            NetStream::Tcp(s) => s.flush(),
            NetStream::Unix(s) => s.flush(),
        }
    }
}

struct Conn {
    reader: BufReader<NetStream>,
    writer: NetStream,
    next_id: u64,
    /// Why this connection can no longer be trusted (response timeout,
    /// framing failure, id desync). Once set, every call fails fast
    /// with a clear error instead of reading a stale in-flight response
    /// as if it answered the new request; [`FleetClient::reconnect`]
    /// clears it by dialing fresh.
    dead: Option<String>,
}

impl Conn {
    /// Mark the connection dead and tear the socket down (so the server
    /// notices and any late response is discarded by the kernel, not
    /// misread by a later call).
    fn poison(&mut self, why: String) -> ClientError {
        if self.dead.is_none() {
            self.dead = Some(why.clone());
        }
        self.writer.shutdown_both();
        ClientError::Transport(why)
    }
}

/// A blocking remote handle to a [`Fleet`](crate::coordinator::Fleet)
/// served by a [`NetServer`](super::NetServer). Cheap to clone; clones
/// share one connection and serialize their calls.
#[derive(Clone)]
pub struct FleetClient {
    conn: Arc<Mutex<Conn>>,
    cfg: Arc<NetClientConfig>,
    addr: Arc<ListenAddr>,
}

impl FleetClient {
    /// Connect with default [`NetClientConfig`].
    pub fn connect(addr: &ListenAddr) -> Result<FleetClient, ClientError> {
        FleetClient::connect_with(addr, NetClientConfig::default())
    }

    pub fn connect_with(
        addr: &ListenAddr,
        cfg: NetClientConfig,
    ) -> Result<FleetClient, ClientError> {
        let stream = NetStream::connect(addr, &cfg)?;
        stream
            .set_read_timeout(cfg.response_timeout)
            .map_err(|e| ClientError::Transport(e.to_string()))?;
        let reader = BufReader::new(
            stream
                .try_clone()
                .map_err(|e| ClientError::Transport(e.to_string()))?,
        );
        Ok(FleetClient {
            conn: Arc::new(Mutex::new(Conn {
                reader,
                writer: stream,
                next_id: 1,
                dead: None,
            })),
            cfg: Arc::new(cfg),
            addr: Arc::new(addr.clone()),
        })
    }

    /// The address this client connected to.
    pub fn addr(&self) -> &ListenAddr {
        &self.addr
    }

    /// One request/response exchange. Holding the lock across both
    /// halves is what guarantees in-order, one-outstanding framing.
    ///
    /// A failure that leaves the stream untrustworthy — response
    /// timeout (the late response would answer the *next* call),
    /// transport/framing breakage, or an id desync — poisons the shared
    /// connection: every later call fails fast with a "connection is
    /// dead" transport error until [`reconnect`](FleetClient::reconnect).
    fn call(&self, verb: Verb, payload: Json) -> Result<Json, ClientError> {
        let mut conn = self
            .conn
            .lock()
            .map_err(|_| ClientError::Transport("client connection poisoned".into()))?;
        if let Some(why) = &conn.dead {
            return Err(ClientError::Transport(format!(
                "connection to {} is dead ({why}); reconnect to retry",
                self.addr
            )));
        }
        let id = conn.next_id;
        conn.next_id += 1;
        let line = RequestFrame::new(id, verb, payload).to_line();
        if let Err(e) = conn
            .writer
            .write_all(line.as_bytes())
            .and_then(|_| conn.writer.flush())
        {
            return Err(conn.poison(format!("send failed: {e}")));
        }
        let resp_line = match protocol::read_frame_line(&mut conn.reader, self.cfg.max_line_bytes)
        {
            Ok(Some(l)) => l,
            Ok(None) => return Err(conn.poison("server closed the connection".into())),
            Err(ProtocolError::Timeout) => {
                return Err(conn.poison(format!(
                    "no response within {:?}",
                    self.cfg.response_timeout
                )))
            }
            Err(e) => {
                // Oversized/truncated/io all leave the line framing
                // unrecoverable mid-stream.
                conn.poison(e.to_string());
                return Err(ClientError::Protocol(e));
            }
        };
        let resp = ResponseFrame::parse(&resp_line).map_err(ClientError::Protocol)?;
        if resp.id != id {
            // id 0 is the server's out-of-band channel for framing
            // errors; anything else means the stream is out of sync.
            return match resp.body {
                Err(e) => Err(ClientError::Remote(e)),
                Ok(_) => Err(conn.poison(format!(
                    "response id {} does not match call id {id}",
                    resp.id
                ))),
            };
        }
        match resp.body {
            Ok(body) => Ok(body),
            Err(wire) => match wire.to_submit() {
                Some(se) => Err(ClientError::Submit(se)),
                None => Err(ClientError::Remote(wire)),
            },
        }
    }

    /// Whether the shared connection has been declared dead — poisoned
    /// by a response timeout, a framing failure, or an id desync.
    pub fn is_dead(&self) -> bool {
        self.conn.lock().map(|c| c.dead.is_some()).unwrap_or(true)
    }

    /// Replace a dead (or live) connection with a freshly dialed one,
    /// shared by all clones of this client. Server-side tickets from
    /// the old connection are settled by the server when it notices the
    /// close, so outstanding [`RemoteTicket`]s will report not-found.
    pub fn reconnect(&self) -> Result<(), ClientError> {
        let stream = NetStream::connect(&self.addr, &self.cfg)?;
        stream
            .set_read_timeout(self.cfg.response_timeout)
            .map_err(|e| ClientError::Transport(e.to_string()))?;
        let reader = BufReader::new(
            stream
                .try_clone()
                .map_err(|e| ClientError::Transport(e.to_string()))?,
        );
        let mut conn = self
            .conn
            .lock()
            .map_err(|_| ClientError::Transport("client connection poisoned".into()))?;
        conn.writer.shutdown_both();
        // Ids keep counting up, so frames from the two connection
        // generations can never be confused.
        *conn = Conn {
            reader,
            writer: stream,
            next_id: conn.next_id,
            dead: None,
        };
        Ok(())
    }

    // ------------------------------------------------- data plane --

    /// Submit a request to the remote fleet. Mirrors
    /// [`Fleet::submit`](crate::coordinator::Fleet::submit): a refusal
    /// is a typed [`SubmitError`] via [`ClientError::Submit`].
    pub fn submit(&self, req: &Request) -> Result<RemoteTicket, ClientError> {
        let body = self.call(Verb::Submit, protocol::encode_submit(req))?;
        let id = body
            .get("ticket")
            .and_then(Json::as_u64)
            .ok_or_else(|| bad_body("submit response missing 'ticket'"))?;
        Ok(RemoteTicket {
            id,
            device: body
                .get("device")
                .and_then(Json::as_str)
                .map(str::to_string),
            client: self.clone(),
        })
    }

    // ---------------------------------------------- control plane --

    /// Epoch-stamped remote topology snapshot.
    pub fn topology(&self) -> Result<TopologyDesc, ClientError> {
        let body = self.call(Verb::Topology, Json::obj())?;
        TopologyDesc::from_json(&body).map_err(ClientError::Protocol)
    }

    /// Current topology epoch.
    pub fn epoch(&self) -> Result<u64, ClientError> {
        Ok(self.topology()?.epoch)
    }

    /// Remote fleet-wide [`WireStats`].
    pub fn stats(&self) -> Result<WireStats, ClientError> {
        let body = self.call(Verb::Stats, Json::obj())?;
        WireStats::from_json(&body).map_err(ClientError::Protocol)
    }

    /// Add a registry device to the remote fleet; returns
    /// `(member id, new epoch)`.
    pub fn add_member(
        &self,
        device: &str,
        policy: &TilePolicy,
    ) -> Result<(u64, u64), ClientError> {
        let body = self.call(
            Verb::AddMember,
            Json::obj()
                .set("device", device)
                .set("policy", protocol::encode_policy(policy)),
        )?;
        let member = body
            .get("member")
            .and_then(Json::as_u64)
            .ok_or_else(|| bad_body("add_member response missing 'member'"))?;
        let epoch = body
            .get("epoch")
            .and_then(Json::as_u64)
            .ok_or_else(|| bad_body("add_member response missing 'epoch'"))?;
        Ok((member, epoch))
    }

    /// Remove a member; returns the new epoch.
    pub fn remove_member(&self, device: &str, mode: DrainMode) -> Result<u64, ClientError> {
        let body = self.call(
            Verb::RemoveMember,
            Json::obj()
                .set("device", device)
                .set("mode", protocol::drain_mode_name(mode)),
        )?;
        body.get("epoch")
            .and_then(Json::as_u64)
            .ok_or_else(|| bad_body("remove_member response missing 'epoch'"))
    }

    /// Stop admissions to a member without removing it; returns the new
    /// epoch.
    pub fn drain(&self, device: &str) -> Result<u64, ClientError> {
        let body = self.call(Verb::Drain, Json::obj().set("device", device))?;
        body.get("epoch")
            .and_then(Json::as_u64)
            .ok_or_else(|| bad_body("drain response missing 'epoch'"))
    }

    /// Hot-swap a member's tuned tile from a fresh outcome; returns the
    /// tile now in effect (None if the outcome had no tile for it).
    pub fn retune(
        &self,
        device: &str,
        outcome: &TuningOutcome,
    ) -> Result<Option<TileDim>, ClientError> {
        let body = self.call(
            Verb::Retune,
            Json::obj()
                .set("device", device)
                .set("outcome", outcome.to_json()),
        )?;
        match body.get("tile") {
            None | Some(Json::Null) => Ok(None),
            Some(t) => {
                let s = t
                    .as_str()
                    .ok_or_else(|| bad_body("retune response 'tile' must be a string"))?;
                s.parse::<TileDim>()
                    .map(Some)
                    .map_err(|e: String| bad_body(format!("retune response tile: {e}")))
            }
        }
    }

    /// Swap the remote scheduler by registry name.
    pub fn set_scheduler(&self, name: &str) -> Result<(), ClientError> {
        self.call(Verb::SetScheduler, Json::obj().set("name", name))?;
        Ok(())
    }

    /// Swap the remote admission policy by registry name.
    pub fn set_admission(&self, name: &str, timeout: Duration) -> Result<(), ClientError> {
        self.call(
            Verb::SetAdmission,
            Json::obj()
                .set("name", name)
                .set("timeout_ms", timeout.as_secs_f64() * 1e3),
        )?;
        Ok(())
    }

    /// Reconfigure remote work stealing.
    pub fn set_steal_config(&self, enabled: bool, threshold: usize) -> Result<(), ClientError> {
        self.call(
            Verb::SetStealConfig,
            Json::obj().set("enabled", enabled).set("threshold", threshold),
        )?;
        Ok(())
    }

    /// Snapshot the remote autoscaler's knobs and counters. A server
    /// running without one answers not-found ([`ClientError::Remote`]
    /// with kind `not-found`).
    pub fn autoscaler(&self) -> Result<AutoscalerDesc, ClientError> {
        let body = self.call(Verb::Autoscaler, Json::obj())?;
        AutoscalerDesc::from_json(&body).map_err(ClientError::Protocol)
    }

    /// Apply a partial [`AutoscalerUpdate`] to the remote autoscaler;
    /// returns the post-update state (no second round trip needed).
    /// An invalid resulting band is a remote error, not a poisoned
    /// connection.
    pub fn set_autoscaler(&self, update: &AutoscalerUpdate) -> Result<AutoscalerDesc, ClientError> {
        let body = self.call(
            Verb::SetAutoscaler,
            protocol::encode_autoscaler_update(update),
        )?;
        AutoscalerDesc::from_json(&body).map_err(ClientError::Protocol)
    }
}

fn bad_body(msg: impl Into<String>) -> ClientError {
    ClientError::Protocol(ProtocolError::Malformed(msg.into()))
}

/// The remote analogue of [`Ticket`](crate::coordinator::Ticket): a
/// stable server-side ticket id plus the connection to poll it on.
pub struct RemoteTicket {
    id: u64,
    device: Option<String>,
    client: FleetClient,
}

impl RemoteTicket {
    /// Server-side ticket id (stable across the wire).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// The device the scheduler picked at admission, when known.
    pub fn device_id(&self) -> Option<&str> {
        self.device.as_deref()
    }

    fn poll(&self, verb: Verb, budget: Option<Duration>) -> Result<Option<Image<f32>>, ClientError> {
        let payload = Json::obj().set("ticket", self.id);
        let payload = match budget {
            Some(b) => payload.set("timeout_ms", b.as_secs_f64() * 1e3),
            None => payload,
        };
        let body = self.client.call(verb, payload)?;
        let done = body
            .get("done")
            .and_then(Json::as_bool)
            .ok_or_else(|| bad_body("wait response missing 'done'"))?;
        if !done {
            return Ok(None);
        }
        let img = body
            .get("image")
            .ok_or_else(|| bad_body("completed wait response missing 'image'"))?;
        protocol::decode_image(img)
            .map(Some)
            .map_err(ClientError::Protocol)
    }

    /// Block until the result arrives (looping bounded server-side
    /// polls), consuming the ticket — the remote mirror of
    /// [`Ticket::wait`](crate::coordinator::Ticket::wait).
    pub fn wait(self) -> Result<Image<f32>, ClientError> {
        loop {
            if let Some(img) = self.poll(Verb::Wait, Some(self.client.cfg.wait_poll))? {
                return Ok(img);
            }
        }
    }

    /// One bounded wait; `Ok(None)` means not done yet (ticket stays
    /// valid).
    pub fn wait_timeout(&self, budget: Duration) -> Result<Option<Image<f32>>, ClientError> {
        self.poll(Verb::Wait, Some(budget))
    }

    /// Non-blocking poll; `Ok(None)` means not done yet.
    pub fn try_wait(&self) -> Result<Option<Image<f32>>, ClientError> {
        self.poll(Verb::TryWait, None)
    }

    /// Request cancellation. The ticket still resolves (as cancelled) —
    /// observe it via `wait`/`try_wait`.
    pub fn cancel(&self) -> Result<(), ClientError> {
        self.client.call(Verb::Cancel, Json::obj().set("ticket", self.id))?;
        Ok(())
    }
}
