//! The front tier: one process fanning requests out over N fleet
//! servers ("shards") — `tilekit front --shards a:port,b:port`.
//!
//! Routing is a consistent-hash ring over **request shape**
//! ([`RequestKey`]: interpolator, source dims, scale), so every request
//! for the same shape lands on the same shard — keeping that shard's
//! batcher full of identical work, which is exactly what the tuned-tile
//! pipelines want. Each shard contributes `VNODES` virtual nodes, so
//! removing one shard only remaps its own arc of the ring.
//!
//! Health is the shard's own control plane: the tier polls each shard's
//! `topology()` — a shard is routable while it answers and has at least
//! one non-draining member. Dead or draining shards are routed around
//! by walking the ring to the next live one, and a submit that hits a
//! just-died shard retries on the survivor, so a drain loses zero
//! tickets. [`merged_stats`](FrontTier::merged_stats) folds every
//! shard's [`WireStats`] into one fleet-of-fleets view.

use super::client::{ClientError, FleetClient, NetClientConfig, RemoteTicket};
use super::protocol::WireStats;
use super::server::ListenAddr;
use crate::coordinator::{Request, RequestKey};
use crate::util::fnv1a64;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

/// Virtual nodes per shard on the hash ring.
pub const VNODES: usize = 64;

/// Stable 64-bit fingerprint of a request shape — the ring key.
pub fn shape_hash(key: &RequestKey) -> u64 {
    let mut bytes: Vec<u8> = Vec::with_capacity(24);
    bytes.extend_from_slice(key.kernel.label().as_bytes());
    bytes.push(0);
    bytes.extend_from_slice(&key.src.0.to_le_bytes());
    bytes.extend_from_slice(&key.src.1.to_le_bytes());
    bytes.extend_from_slice(&key.scale.to_le_bytes());
    fnv1a64(bytes)
}

/// The pure routing core: a sorted vnode ring mapping hashes to shard
/// indices, independent of any live connection (unit-testable).
pub struct Ring {
    /// `(vnode hash, shard index)`, sorted by hash.
    entries: Vec<(u64, usize)>,
}

impl Ring {
    /// Build the ring from one stable label per shard (the address
    /// string) — same labels, same ring, on every tier instance.
    pub fn new(labels: &[String], vnodes: usize) -> Ring {
        let mut entries: Vec<(u64, usize)> = Vec::with_capacity(labels.len() * vnodes);
        for (i, label) in labels.iter().enumerate() {
            for v in 0..vnodes {
                let key = format!("{label}#{v}");
                entries.push((fnv1a64(key.into_bytes()), i));
            }
        }
        entries.sort_unstable();
        Ring { entries }
    }

    /// The shard owning `hash`, skipping shards `live` rejects. Walks
    /// clockwise from the owning vnode, so the same hash maps to the
    /// same shard until that shard dies — and deterministically fails
    /// over to its ring successor when it does.
    pub fn route(&self, hash: u64, live: impl Fn(usize) -> bool) -> Option<usize> {
        if self.entries.is_empty() {
            return None;
        }
        let start = self.entries.partition_point(|&(h, _)| h < hash);
        for off in 0..self.entries.len() {
            let (_, shard) = self.entries[(start + off) % self.entries.len()];
            if live(shard) {
                return Some(shard);
            }
        }
        None
    }
}

struct ShardState {
    addr: ListenAddr,
    client: FleetClient,
    alive: AtomicBool,
    draining: AtomicBool,
    epoch: AtomicU64,
}

/// One shard's health as the tier currently sees it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardView {
    pub addr: String,
    pub alive: bool,
    pub draining: bool,
    pub epoch: u64,
}

/// Tunables for a [`FrontTier`].
#[derive(Debug, Clone)]
pub struct FrontTierConfig {
    /// Background health-poll cadence; `None` = no thread, the caller
    /// drives [`poll_once`](FrontTier::poll_once) (tests do this for
    /// determinism).
    pub health_poll: Option<Duration>,
    /// Per-shard client settings.
    pub client: NetClientConfig,
}

impl Default for FrontTierConfig {
    fn default() -> FrontTierConfig {
        FrontTierConfig {
            health_poll: Some(Duration::from_millis(200)),
            client: NetClientConfig::default(),
        }
    }
}

/// A consistent-hash front tier over N fleet servers.
pub struct FrontTier {
    shards: Arc<Vec<ShardState>>,
    ring: Ring,
    stop: Arc<AtomicBool>,
    poller: Option<thread::JoinHandle<()>>,
}

impl FrontTier {
    /// Connect to every shard and build the ring. All shards must be
    /// reachable at startup; afterwards the tier tolerates deaths.
    pub fn connect(addrs: &[ListenAddr], cfg: FrontTierConfig) -> Result<FrontTier, ClientError> {
        if addrs.is_empty() {
            return Err(ClientError::Transport("front tier needs at least one shard".into()));
        }
        let mut shards = Vec::with_capacity(addrs.len());
        for addr in addrs {
            let client = FleetClient::connect_with(addr, cfg.client.clone())?;
            shards.push(ShardState {
                addr: addr.clone(),
                client,
                alive: AtomicBool::new(true),
                draining: AtomicBool::new(false),
                epoch: AtomicU64::new(0),
            });
        }
        let labels: Vec<String> = addrs.iter().map(|a| a.to_string()).collect();
        let shards = Arc::new(shards);
        let stop = Arc::new(AtomicBool::new(false));
        let tier = FrontTier {
            ring: Ring::new(&labels, VNODES),
            poller: match cfg.health_poll {
                None => None,
                Some(period) => {
                    let shards = Arc::clone(&shards);
                    let stop = Arc::clone(&stop);
                    Some(
                        thread::Builder::new()
                            .name("front-health".into())
                            .spawn(move || {
                                while !stop.load(Ordering::SeqCst) {
                                    poll_all(&shards);
                                    thread::sleep(period);
                                }
                            })
                            .map_err(|e| ClientError::Transport(e.to_string()))?,
                    )
                }
            },
            shards,
            stop,
        };
        tier.poll_once();
        Ok(tier)
    }

    /// One synchronous health sweep over every shard.
    pub fn poll_once(&self) {
        poll_all(&self.shards);
    }

    fn routable(&self, i: usize) -> bool {
        self.shards[i].alive.load(Ordering::SeqCst)
            && !self.shards[i].draining.load(Ordering::SeqCst)
    }

    /// The live shard that owns this request shape.
    pub fn route_for(&self, key: &RequestKey) -> Option<usize> {
        self.ring.route(shape_hash(key), |i| self.routable(i))
    }

    /// Submit through the owning shard; fails over (marking the shard
    /// dead) if that shard's transport is gone. Returns the shard index
    /// actually used alongside the ticket.
    pub fn submit(&self, req: &Request) -> Result<(usize, RemoteTicket), ClientError> {
        let hash = shape_hash(&req.key());
        for _ in 0..self.shards.len() {
            let Some(i) = self.ring.route(hash, |i| self.routable(i)) else {
                break;
            };
            match self.shards[i].client.submit(req) {
                Ok(t) => return Ok((i, t)),
                // The shard vanished between health polls: mark it and
                // let the ring fail over.
                Err(ClientError::Transport(_)) | Err(ClientError::Protocol(_)) => {
                    self.shards[i].alive.store(false, Ordering::SeqCst);
                }
                // Typed refusals (saturated, shutting down, ...) come
                // from a *live* shard — propagate, don't reroute, so
                // backpressure still means something.
                Err(e) => return Err(e),
            }
        }
        Err(ClientError::Transport("no live shard for this request shape".into()))
    }

    /// Shard count.
    pub fn len(&self) -> usize {
        self.shards.len()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    /// Direct client handle to shard `i` (control-plane pass-through:
    /// drain, retune, remove_member against one shard).
    pub fn client(&self, i: usize) -> &FleetClient {
        &self.shards[i].client
    }

    /// Current health snapshot, one entry per shard.
    pub fn shard_views(&self) -> Vec<ShardView> {
        self.shards
            .iter()
            .map(|s| ShardView {
                addr: s.addr.to_string(),
                alive: s.alive.load(Ordering::SeqCst),
                draining: s.draining.load(Ordering::SeqCst),
                epoch: s.epoch.load(Ordering::SeqCst),
            })
            .collect()
    }

    /// Fold every live shard's stats into one fleet-of-fleets view.
    pub fn merged_stats(&self) -> WireStats {
        let mut merged = WireStats::default();
        for s in self.shards.iter() {
            if !s.alive.load(Ordering::SeqCst) {
                continue;
            }
            if let Ok(stats) = s.client.stats() {
                merged.merge_from(&stats);
            }
        }
        merged
    }

    /// Stop the health poller.
    pub fn shutdown(mut self) {
        self.stop_poller();
    }

    fn stop_poller(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.poller.take() {
            let _ = h.join();
        }
    }
}

impl Drop for FrontTier {
    fn drop(&mut self) {
        self.stop_poller();
    }
}

fn poll_all(shards: &[ShardState]) {
    for s in shards {
        // topology() is replay-safe, so the client redials a dead
        // connection itself (with backoff) — one call both probes the
        // shard and gives a returned shard its arc of the ring back.
        match s.client.topology() {
            Ok(t) => {
                s.epoch.store(t.epoch, Ordering::SeqCst);
                s.draining.store(t.is_draining(), Ordering::SeqCst);
                s.alive.store(true, Ordering::SeqCst);
            }
            Err(_) => s.alive.store(false, Ordering::SeqCst),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::RequestKey;
    use crate::image::Interpolator;

    fn ring3() -> (Ring, Vec<String>) {
        let labels: Vec<String> = ["127.0.0.1:7441", "127.0.0.1:7442", "127.0.0.1:7443"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        (Ring::new(&labels, VNODES), labels)
    }

    #[test]
    fn routing_is_stable_and_total() {
        let (ring, _) = ring3();
        for scale in 1..50u32 {
            let key = RequestKey {
                kernel: Interpolator::Bilinear,
                src: (64, 64),
                scale,
            };
            let a = ring.route(shape_hash(&key), |_| true).unwrap();
            let b = ring.route(shape_hash(&key), |_| true).unwrap();
            assert_eq!(a, b, "same shape must route to the same shard");
            assert!(a < 3);
        }
    }

    #[test]
    fn ring_spreads_shapes_across_shards() {
        let (ring, _) = ring3();
        let mut hit = [false; 3];
        for scale in 1..200u32 {
            for kernel in [Interpolator::Nearest, Interpolator::Bilinear, Interpolator::Bicubic] {
                let key = RequestKey { kernel, src: (64, 64), scale };
                hit[ring.route(shape_hash(&key), |_| true).unwrap()] = true;
            }
        }
        assert_eq!(hit, [true; 3], "600 shapes should touch every shard");
    }

    #[test]
    fn dead_shard_fails_over_deterministically_and_recovers() {
        let (ring, _) = ring3();
        let key = RequestKey {
            kernel: Interpolator::Bilinear,
            src: (128, 96),
            scale: 2,
        };
        let h = shape_hash(&key);
        let owner = ring.route(h, |_| true).unwrap();
        let fail1 = ring.route(h, |i| i != owner).unwrap();
        assert_ne!(fail1, owner);
        // Failover is itself stable...
        assert_eq!(ring.route(h, |i| i != owner).unwrap(), fail1);
        // ...and the owner gets its arc back when it returns.
        assert_eq!(ring.route(h, |_| true).unwrap(), owner);
        // All shards down: nothing to route to.
        assert_eq!(ring.route(h, |_| false), None);
    }

    #[test]
    fn empty_ring_routes_nowhere() {
        let ring = Ring::new(&[], VNODES);
        assert_eq!(ring.route(7, |_| true), None);
    }

    #[test]
    fn shape_hash_separates_components() {
        let base = RequestKey {
            kernel: Interpolator::Bilinear,
            src: (64, 64),
            scale: 2,
        };
        let h = shape_hash(&base);
        assert_eq!(h, shape_hash(&base));
        assert_ne!(h, shape_hash(&RequestKey { scale: 3, ..base }));
        assert_ne!(h, shape_hash(&RequestKey { src: (64, 32), ..base }));
        assert_ne!(
            h,
            shape_hash(&RequestKey {
                kernel: Interpolator::Nearest,
                ..base
            })
        );
    }
}
