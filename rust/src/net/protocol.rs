//! The wire protocol: versioned, line-delimited JSON frames over the
//! serde-free [`crate::codec::json`] substrate.
//!
//! Every frame is one compact JSON object on one `\n`-terminated line.
//! A request frame carries a caller-chosen id, a verb, and a payload;
//! the response echoes the id with either an `ok` body or a typed `err`
//! object. [`SubmitError`](crate::coordinator::SubmitError) round-trips
//! losslessly through the error kinds, so a remote caller sees the same
//! typed backpressure as an in-process one.
//!
//! ```text
//! -> {"v":1,"id":7,"verb":"submit","payload":{"kernel":"bilinear",...}}
//! <- {"v":1,"id":7,"ok":{"ticket":42,"device":"gtx260"}}
//! <- {"v":1,"id":8,"err":{"kind":"saturated","msg":"admission queue saturated"}}
//! ```
//!
//! Payload schemas per verb (request -> ok-response):
//!
//! | verb               | request payload                              | ok payload |
//! |--------------------|----------------------------------------------|------------|
//! | `submit`           | `{kernel, scale, priority?, deadline_ms?, image}` | `{ticket, device?}` |
//! | `wait`             | `{ticket, timeout_ms?}`                      | `{done, image?}` |
//! | `try_wait`         | `{ticket}`                                   | `{done, image?}` |
//! | `cancel`           | `{ticket}`                                   | `{cancelled}` |
//! | `topology`         | `{}`                                         | `{epoch, members:[...]}` |
//! | `add_member`       | `{device, policy}`                           | `{member, epoch}` |
//! | `remove_member`    | `{device, mode}`                             | `{epoch}` |
//! | `drain`            | `{device}`                                   | `{epoch}` |
//! | `retune`           | `{device, outcome}`                          | `{tile}` |
//! | `set_scheduler`    | `{name}`                                     | `{ok}` |
//! | `set_admission`    | `{name, timeout_ms?}`                        | `{ok}` |
//! | `set_steal_config` | `{enabled, threshold}`                       | `{ok}` |
//! | `stats`            | `{}`                                         | counters + latency |
//! | `autoscaler`       | `{}`                                         | [`AutoscalerDesc`] |
//! | `set_autoscaler`   | partial [`AutoscalerUpdate`] fields          | [`AutoscalerDesc`] |
//! | `hello`            | `{max}`                                      | `{version}` |
//!
//! An image is `{"w":W,"h":H,"px":[row-major f32 ...]}` (v1) or a
//! binary block reference (v2, below). A tile policy is `"portable"`,
//! `{"fixed":"32x4"}`, or `{"per_device":<TuningOutcome>}`. Frame
//! parsing never panics: malformed input, an oversized line, or a
//! stream truncated mid-line all surface as a typed [`ProtocolError`].
//!
//! # Protocol v2 frame layout
//!
//! A session starts at v1. A client that wants v2 sends `hello` as its
//! first frame (payload `{"max":2}`); the server answers `{"version":v}`
//! with `v = min(client max, server max)` (see [`negotiate`]) and the
//! session switches to `v`. A pre-v2 server instead answers the unknown
//! verb with an id-0 `protocol` error and keeps the connection open, so
//! the client falls back to v1 — old peers keep working in both
//! directions.
//!
//! In a v2 session a frame may carry a binary block after its header
//! line: the header gains `"payload_bytes":N` and exactly `N` raw bytes
//! follow the newline. Image pixels travel in that block as a 4-byte
//! little-endian u32 pixel count followed by count x 4 bytes of
//! little-endian f32, row-major ([`encode_image_blob`]); the image
//! header shrinks to `{"w":W,"h":H,"bin":true}`. At most one image
//! rides per frame (a `submit` request, or a `wait`/`try_wait`
//! response), so header and block pair unambiguously. Read the block
//! with [`read_payload`], which mirrors [`read_frame_line`]'s
//! Oversized/Truncated/stall discipline. v2 also lifts the
//! one-outstanding-call rule: clients pipeline many requests per
//! connection and responses may return out of order (ids do the
//! matching).
//!
//! ```text
//! -> {"v":1,"id":1,"verb":"hello","payload":{"max":2}}
//! <- {"v":1,"id":1,"ok":{"version":2}}
//! -> {"v":2,"id":2,"verb":"submit","payload":{...,"image":{"w":64,"h":64,"bin":true}},"payload_bytes":16388}
//!    <16388 raw bytes: 4-byte LE pixel count, then 4096 LE f32 pixels>
//! <- {"v":2,"id":2,"ok":{"ticket":1,"device":"gtx260"}}
//! ```

use crate::codec::json::Json;
use crate::coordinator::{
    AutoscalerUpdate, AutoscalerView, DrainMode, Priority, Request, RequestKey, ServingStats,
    SubmitError, TilePolicy, TopologyView,
};
use crate::image::{Image, Interpolator};
use crate::tiling::TileDim;
use std::fmt;
use std::io::{BufRead, Read};
use std::time::Duration;

/// The baseline wire format version: line-delimited JSON frames, one
/// outstanding call per connection. Every peer speaks it; frames from a
/// version past [`PROTOCOL_V2`] are rejected with
/// [`ProtocolError::Version`].
pub const PROTOCOL_VERSION: u64 = 1;

/// The highest protocol revision this build speaks: pipelined frames
/// plus binary image payloads, entered via a `hello` exchange (see the
/// module docs).
pub const PROTOCOL_V2: u64 = 2;

/// Pick the version a `hello` exchange pins the session to: the smaller
/// of the two maxima, floored at the baseline [`PROTOCOL_VERSION`].
pub fn negotiate(client_max: u64, server_max: u64) -> u64 {
    client_max.min(server_max).max(PROTOCOL_VERSION)
}

/// Encode the `hello` request payload (`{"max":N}`).
pub fn encode_hello(max: u64) -> Json {
    Json::obj().set("max", max)
}

/// The peer's maximum version from a `hello` payload. A missing or
/// mistyped `max` counts as the baseline version rather than an error:
/// the exchange's whole job is tolerating peers that know less.
pub fn decode_hello_max(j: &Json) -> u64 {
    j.get("max").and_then(Json::as_u64).unwrap_or(PROTOCOL_VERSION)
}

/// How a client ships image pixels: `Binary` opens each connection with
/// a `hello` exchange and uses v2 binary blocks when the server agrees;
/// `Json` skips negotiation and speaks pure v1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PayloadEncoding {
    /// Pixels as a JSON number array (protocol v1).
    Json,
    /// Pixels as a little-endian f32 block (protocol v2, negotiated).
    Binary,
}

impl PayloadEncoding {
    pub fn name(self) -> &'static str {
        match self {
            PayloadEncoding::Json => "json",
            PayloadEncoding::Binary => "binary",
        }
    }

    pub fn parse(s: &str) -> Option<PayloadEncoding> {
        match s {
            "json" => Some(PayloadEncoding::Json),
            "binary" => Some(PayloadEncoding::Binary),
            _ => None,
        }
    }
}

/// Default per-line byte cap. A 512x512 f32 image serializes to a few
/// MiB of JSON, so the cap is generous — it bounds memory per
/// connection, not normal payloads.
pub const DEFAULT_MAX_LINE_BYTES: usize = 8 * 1024 * 1024;

/// Hard cap on decoded image pixel count (8192x8192). Every pixel costs
/// at least two bytes on the wire, so no frame under any sane line cap
/// can legitimately carry more; it also keeps wrap-prone `w*h`
/// arithmetic (e.g. `2^32 x 2^32`) from ever reaching [`Image`]
/// construction.
pub const MAX_IMAGE_PIXELS: u64 = 1 << 26;

/// Largest millisecond duration accepted off the wire (~31.7 years).
/// `Duration::from_secs_f64` panics on values that overflow a
/// `Duration`, so anything bigger is treated as a malformed frame, not
/// a real timeout.
pub const MAX_DURATION_MS: f64 = 1e12;

/// Decode a wire `*_ms` field into a [`Duration`], rejecting NaN,
/// infinities, negatives, and magnitudes past [`MAX_DURATION_MS`] —
/// the values `Duration::from_secs_f64` would panic on. Untrusted input
/// must come through here rather than calling `from_secs_f64` directly.
pub fn duration_from_ms(ms: f64, field: &str) -> Result<Duration, ProtocolError> {
    if !ms.is_finite() || !(0.0..=MAX_DURATION_MS).contains(&ms) {
        return Err(malformed(format!("bad {field} {ms}")));
    }
    // analyze::allow(duration-through-bounds): this IS the blessed
    // constructor — the guard above rejects every input from_secs_f64
    // panics on (NaN, negatives, > MAX_DURATION_MS).
    Ok(Duration::from_secs_f64(ms / 1e3))
}

/// Clamp a millisecond value into a [`Duration`] instead of rejecting:
/// NaN and negatives become zero, magnitudes past [`MAX_DURATION_MS`]
/// saturate to the cap. For config fields and operator-supplied CLI
/// knobs, where the right response to a wild value is "bound it", not
/// "error out mid-run". Wire fields keep using [`duration_from_ms`] so
/// hostile peers get a typed rejection.
pub fn saturating_duration_from_ms(ms: f64) -> Duration {
    let ms = if ms.is_finite() { ms.clamp(0.0, MAX_DURATION_MS) } else { 0.0 };
    // analyze::allow(duration-through-bounds): NaN/negative/overflow all
    // eliminated above; from_secs_f64 cannot panic on this input.
    Duration::from_secs_f64(ms / 1e3)
}

/// Every operation the wire protocol can carry: the data plane
/// (`submit`/`wait`/`try_wait`/`cancel`) plus the full
/// [`FleetController`](crate::coordinator::FleetController) surface.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verb {
    Submit,
    Wait,
    TryWait,
    Cancel,
    Topology,
    AddMember,
    RemoveMember,
    Drain,
    Retune,
    SetScheduler,
    SetAdmission,
    SetStealConfig,
    Stats,
    Autoscaler,
    SetAutoscaler,
    /// Version negotiation (v2): first frame on a connection that wants
    /// to speak past the baseline version.
    Hello,
}

impl Verb {
    pub const ALL: [Verb; 16] = [
        Verb::Submit,
        Verb::Wait,
        Verb::TryWait,
        Verb::Cancel,
        Verb::Topology,
        Verb::AddMember,
        Verb::RemoveMember,
        Verb::Drain,
        Verb::Retune,
        Verb::SetScheduler,
        Verb::SetAdmission,
        Verb::SetStealConfig,
        Verb::Stats,
        Verb::Autoscaler,
        Verb::SetAutoscaler,
        Verb::Hello,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Verb::Submit => "submit",
            Verb::Wait => "wait",
            Verb::TryWait => "try_wait",
            Verb::Cancel => "cancel",
            Verb::Topology => "topology",
            Verb::AddMember => "add_member",
            Verb::RemoveMember => "remove_member",
            Verb::Drain => "drain",
            Verb::Retune => "retune",
            Verb::SetScheduler => "set_scheduler",
            Verb::SetAdmission => "set_admission",
            Verb::SetStealConfig => "set_steal_config",
            Verb::Stats => "stats",
            Verb::Autoscaler => "autoscaler",
            Verb::SetAutoscaler => "set_autoscaler",
            Verb::Hello => "hello",
        }
    }

    pub fn parse(s: &str) -> Option<Verb> {
        Verb::ALL.iter().copied().find(|v| v.name() == s)
    }
}

impl fmt::Display for Verb {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Why a frame could not be read or decoded. Typed so transports can
/// tell a timeout (keep polling) from corruption (close the connection).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ProtocolError {
    /// Underlying transport error.
    Io(String),
    /// The socket read timed out with no bytes consumed — the caller
    /// decides whether the connection is idle-dead or just quiet.
    Timeout,
    /// A line exceeded the configured byte cap.
    Oversized { limit: usize },
    /// The stream ended mid-line (peer died between bytes of a frame).
    Truncated,
    /// The line is not a valid frame (bad JSON, missing fields, unknown
    /// verb or error kind).
    Malformed(String),
    /// The peer speaks a different protocol version.
    Version { got: u64 },
}

impl fmt::Display for ProtocolError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ProtocolError::Io(e) => write!(f, "transport error: {e}"),
            ProtocolError::Timeout => write!(f, "read timed out"),
            ProtocolError::Oversized { limit } => {
                write!(f, "frame exceeds the {limit}-byte line cap")
            }
            ProtocolError::Truncated => write!(f, "stream truncated mid-frame"),
            ProtocolError::Malformed(m) => write!(f, "malformed frame: {m}"),
            ProtocolError::Version { got } => write!(
                f,
                "peer speaks protocol version {got}, this end speaks up to {PROTOCOL_V2}"
            ),
        }
    }
}

impl std::error::Error for ProtocolError {}

fn malformed(msg: impl Into<String>) -> ProtocolError {
    ProtocolError::Malformed(msg.into())
}

/// The typed error payload of a response frame. The five
/// [`SubmitError`] variants map 1:1 onto the first five kinds, so
/// backpressure semantics survive the wire; the rest describe
/// server-side or protocol-level failures.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireErrorKind {
    Saturated,
    Unsupported,
    DeadlineExceeded,
    Infeasible,
    ShuttingDown,
    /// The named ticket/member does not exist on the server.
    NotFound,
    /// The peer sent a frame this end could not decode.
    Protocol,
    /// The request executed and failed (backend error, shed deadline).
    Failed,
    /// Unexpected server-side error.
    Internal,
}

impl WireErrorKind {
    pub const ALL: [WireErrorKind; 9] = [
        WireErrorKind::Saturated,
        WireErrorKind::Unsupported,
        WireErrorKind::DeadlineExceeded,
        WireErrorKind::Infeasible,
        WireErrorKind::ShuttingDown,
        WireErrorKind::NotFound,
        WireErrorKind::Protocol,
        WireErrorKind::Failed,
        WireErrorKind::Internal,
    ];

    pub fn name(self) -> &'static str {
        match self {
            WireErrorKind::Saturated => "saturated",
            WireErrorKind::Unsupported => "unsupported",
            WireErrorKind::DeadlineExceeded => "deadline",
            WireErrorKind::Infeasible => "infeasible",
            WireErrorKind::ShuttingDown => "shutting-down",
            WireErrorKind::NotFound => "not-found",
            WireErrorKind::Protocol => "protocol",
            WireErrorKind::Failed => "failed",
            WireErrorKind::Internal => "internal",
        }
    }

    pub fn parse(s: &str) -> Option<WireErrorKind> {
        WireErrorKind::ALL.iter().copied().find(|k| k.name() == s)
    }
}

/// A typed error frame body.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireError {
    pub kind: WireErrorKind,
    pub msg: String,
}

impl WireError {
    pub fn new(kind: WireErrorKind, msg: impl Into<String>) -> WireError {
        WireError {
            kind,
            msg: msg.into(),
        }
    }

    /// Encode a [`SubmitError`] so the remote caller can reconstruct it.
    pub fn from_submit(e: &SubmitError) -> WireError {
        let kind = match e {
            SubmitError::Saturated => WireErrorKind::Saturated,
            SubmitError::Unsupported => WireErrorKind::Unsupported,
            SubmitError::DeadlineExceeded => WireErrorKind::DeadlineExceeded,
            SubmitError::Infeasible => WireErrorKind::Infeasible,
            SubmitError::ShuttingDown => WireErrorKind::ShuttingDown,
        };
        WireError::new(kind, e.to_string())
    }

    /// The [`SubmitError`] this frame carries, when its kind is one of
    /// the five submit-path kinds.
    pub fn to_submit(&self) -> Option<SubmitError> {
        match self.kind {
            WireErrorKind::Saturated => Some(SubmitError::Saturated),
            WireErrorKind::Unsupported => Some(SubmitError::Unsupported),
            WireErrorKind::DeadlineExceeded => Some(SubmitError::DeadlineExceeded),
            WireErrorKind::Infeasible => Some(SubmitError::Infeasible),
            WireErrorKind::ShuttingDown => Some(SubmitError::ShuttingDown),
            _ => None,
        }
    }

    fn to_json(&self) -> Json {
        Json::obj()
            .set("kind", self.kind.name())
            .set("msg", self.msg.as_str())
    }

    fn from_json(j: &Json) -> Result<WireError, ProtocolError> {
        let kind_s = j
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| malformed("error frame missing 'kind'"))?;
        let kind = WireErrorKind::parse(kind_s)
            .ok_or_else(|| malformed(format!("unknown error kind '{kind_s}'")))?;
        let msg = j
            .get("msg")
            .and_then(Json::as_str)
            .unwrap_or_default()
            .to_string();
        Ok(WireError { kind, msg })
    }
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.kind.name(), self.msg)
    }
}

impl std::error::Error for WireError {}

/// The version stamp of a parsed frame header. Both revisions are
/// accepted — each end emits at the negotiated session version but must
/// keep parsing baseline frames from an un-negotiated peer.
pub fn frame_version(j: &Json) -> Result<u64, ProtocolError> {
    match j.get("v").and_then(Json::as_u64) {
        Some(v @ (PROTOCOL_VERSION | PROTOCOL_V2)) => Ok(v),
        Some(got) => Err(ProtocolError::Version { got }),
        None => Err(malformed("frame missing 'v'")),
    }
}

/// The byte count of the binary block following this frame's header
/// line (`payload_bytes`), 0 when absent. Consume the block with
/// [`read_payload`] before reading the next frame — even when the
/// header turns out to be otherwise malformed, so the stream stays in
/// sync.
pub fn frame_extra_bytes(j: &Json) -> Result<usize, ProtocolError> {
    match j.get("payload_bytes") {
        None => Ok(0),
        Some(v) => {
            let n = v
                .as_u64()
                .ok_or_else(|| malformed("'payload_bytes' must be a non-negative integer"))?;
            usize::try_from(n)
                .map_err(|_| malformed(format!("payload_bytes {n} does not fit in usize")))
        }
    }
}

/// A request frame: id + verb + payload.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestFrame {
    pub id: u64,
    pub verb: Verb,
    pub payload: Json,
}

impl RequestFrame {
    pub fn new(id: u64, verb: Verb, payload: Json) -> RequestFrame {
        RequestFrame { id, verb, payload }
    }

    /// One compact `\n`-terminated baseline (v1) wire line.
    pub fn to_line(&self) -> String {
        // analyze::allow(no-panic-on-wire): encode side — the bytes come
        // from our own Json encoder (pure UTF-8, no blob), never a peer.
        String::from_utf8(self.to_wire(PROTOCOL_VERSION, None)).unwrap()
    }

    /// Encode at a negotiated session version, appending the binary
    /// block (and its `payload_bytes` stamp) when one is present. v1
    /// frames never carry a block.
    pub fn to_wire(&self, version: u64, blob: Option<&[u8]>) -> Vec<u8> {
        let mut j = Json::obj()
            .set("v", version)
            .set("id", self.id)
            .set("verb", self.verb.name())
            .set("payload", self.payload.clone());
        if let Some(b) = blob {
            j = j.set("payload_bytes", b.len() as u64);
        }
        let mut out = j.to_string().into_bytes();
        out.push(b'\n');
        if let Some(b) = blob {
            out.extend_from_slice(b);
        }
        out
    }

    /// Parse one line (trailing newline optional).
    pub fn parse(line: &str) -> Result<RequestFrame, ProtocolError> {
        let j = Json::parse(line.trim_end_matches(['\r', '\n']))
            .map_err(|e| malformed(e.to_string()))?;
        RequestFrame::from_json(&j)
    }

    /// Decode an already-parsed header object (either version). Readers
    /// that must extract [`frame_extra_bytes`] first use this to avoid
    /// parsing the header twice.
    pub fn from_json(j: &Json) -> Result<RequestFrame, ProtocolError> {
        frame_version(j)?;
        let id = j
            .get("id")
            .and_then(Json::as_u64)
            .ok_or_else(|| malformed("request frame missing 'id'"))?;
        let verb_s = j
            .get("verb")
            .and_then(Json::as_str)
            .ok_or_else(|| malformed("request frame missing 'verb'"))?;
        let verb =
            Verb::parse(verb_s).ok_or_else(|| malformed(format!("unknown verb '{verb_s}'")))?;
        let payload = j.get("payload").cloned().unwrap_or_else(Json::obj);
        Ok(RequestFrame { id, verb, payload })
    }
}

/// A response frame: the request id plus an ok body or a typed error.
#[derive(Debug, Clone, PartialEq)]
pub struct ResponseFrame {
    pub id: u64,
    pub body: Result<Json, WireError>,
}

impl ResponseFrame {
    pub fn ok(id: u64, body: Json) -> ResponseFrame {
        ResponseFrame { id, body: Ok(body) }
    }

    pub fn err(id: u64, e: WireError) -> ResponseFrame {
        ResponseFrame { id, body: Err(e) }
    }

    /// One compact `\n`-terminated baseline (v1) wire line.
    pub fn to_line(&self) -> String {
        // analyze::allow(no-panic-on-wire): encode side — the bytes come
        // from our own Json encoder (pure UTF-8, no blob), never a peer.
        String::from_utf8(self.to_wire(PROTOCOL_VERSION, None)).unwrap()
    }

    /// Encode at a negotiated session version, appending the binary
    /// block (and its `payload_bytes` stamp) when one is present. v1
    /// frames never carry a block.
    pub fn to_wire(&self, version: u64, blob: Option<&[u8]>) -> Vec<u8> {
        let mut j = Json::obj().set("v", version).set("id", self.id);
        j = match &self.body {
            Ok(body) => j.set("ok", body.clone()),
            Err(e) => j.set("err", e.to_json()),
        };
        if let Some(b) = blob {
            j = j.set("payload_bytes", b.len() as u64);
        }
        let mut out = j.to_string().into_bytes();
        out.push(b'\n');
        if let Some(b) = blob {
            out.extend_from_slice(b);
        }
        out
    }

    /// Parse one line (trailing newline optional).
    pub fn parse(line: &str) -> Result<ResponseFrame, ProtocolError> {
        let j = Json::parse(line.trim_end_matches(['\r', '\n']))
            .map_err(|e| malformed(e.to_string()))?;
        ResponseFrame::from_json(&j)
    }

    /// Decode an already-parsed header object (either version). Readers
    /// that must extract [`frame_extra_bytes`] first use this to avoid
    /// parsing the header twice.
    pub fn from_json(j: &Json) -> Result<ResponseFrame, ProtocolError> {
        frame_version(j)?;
        let id = j
            .get("id")
            .and_then(Json::as_u64)
            .ok_or_else(|| malformed("response frame missing 'id'"))?;
        match (j.get("ok"), j.get("err")) {
            (Some(body), None) => Ok(ResponseFrame::ok(id, body.clone())),
            (None, Some(e)) => Ok(ResponseFrame::err(id, WireError::from_json(e)?)),
            _ => Err(malformed("response frame needs exactly one of 'ok'/'err'")),
        }
    }
}

/// Read one `\n`-terminated line, enforcing the byte cap. Returns
/// `Ok(None)` on a clean EOF at a frame boundary; EOF mid-line is
/// [`ProtocolError::Truncated`]; a zero-byte timeout is
/// [`ProtocolError::Timeout`] so callers can keep the connection open.
pub fn read_frame_line(
    r: &mut impl BufRead,
    max_bytes: usize,
) -> Result<Option<String>, ProtocolError> {
    let mut buf: Vec<u8> = Vec::new();
    let mut stalls = 0u32;
    loop {
        let chunk = match r.fill_buf() {
            Ok(c) => c,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if buf.is_empty() {
                    return Err(ProtocolError::Timeout);
                }
                stalls += 1;
                if stalls > MAX_MID_FRAME_STALLS {
                    return Err(ProtocolError::Truncated);
                }
                continue;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(ProtocolError::Io(e.to_string())),
        };
        if chunk.is_empty() {
            return if buf.is_empty() {
                Ok(None)
            } else {
                Err(ProtocolError::Truncated)
            };
        }
        let (take, done) = match chunk.iter().position(|&b| b == b'\n') {
            Some(i) => (i + 1, true),
            None => (chunk.len(), false),
        };
        if buf.len() + take > max_bytes {
            // Drop what we can see of the runaway line; the caller
            // closes the connection, so no need to resynchronize.
            r.consume(take);
            return Err(ProtocolError::Oversized { limit: max_bytes });
        }
        // analyze::allow(no-panic-on-wire): take = position+1 or
        // chunk.len(), both <= chunk.len(); the range cannot overrun.
        buf.extend_from_slice(&chunk[..take]);
        r.consume(take);
        stalls = 0;
        if done {
            let line = String::from_utf8(buf)
                .map_err(|_| malformed("frame line is not valid UTF-8"))?;
            return Ok(Some(line));
        }
    }
}

/// A peer that sends half a frame and hangs must not pin a reader
/// forever: after this many consecutive zero-byte read timeouts
/// mid-frame (~4 min at a 250 ms socket read timeout) the frame is
/// declared truncated and the connection dies.
const MAX_MID_FRAME_STALLS: u32 = 1024;

/// Read the `n`-byte binary block that follows a frame header, with
/// [`read_frame_line`]'s typed-error discipline: a block past the byte
/// cap is [`ProtocolError::Oversized`], EOF inside the block is
/// [`ProtocolError::Truncated`], and a peer that stalls mid-block past
/// the stall budget is also truncated — the header already arrived, so
/// a zero-byte timeout here is never an idle connection.
pub fn read_payload(
    r: &mut impl BufRead,
    n: usize,
    max_bytes: usize,
) -> Result<Vec<u8>, ProtocolError> {
    if n > max_bytes {
        return Err(ProtocolError::Oversized { limit: max_bytes });
    }
    let mut buf = vec![0u8; n];
    let mut filled = 0usize;
    let mut stalls = 0u32;
    while filled < n {
        // analyze::allow(no-panic-on-wire): filled < n = buf.len() is the
        // loop condition; the slice start is always in bounds.
        match r.read(&mut buf[filled..]) {
            Ok(0) => return Err(ProtocolError::Truncated),
            Ok(k) => {
                filled += k;
                stalls = 0;
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                stalls += 1;
                if stalls > MAX_MID_FRAME_STALLS {
                    return Err(ProtocolError::Truncated);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(ProtocolError::Io(e.to_string())),
        }
    }
    Ok(buf)
}

// --------------------------------------------------- payload codecs --

/// Encode an image payload (`{"w":W,"h":H,"px":[...]}`; row-major,
/// pitch dropped).
pub fn encode_image(img: &Image<f32>) -> Json {
    let px: Vec<Json> = img
        .to_dense()
        .into_iter()
        .map(|p| Json::Num(p as f64))
        .collect();
    Json::obj()
        .set("w", img.width())
        .set("h", img.height())
        .set("px", Json::Arr(px))
}

/// Decode what [`encode_image`] wrote.
pub fn decode_image(j: &Json) -> Result<Image<f32>, ProtocolError> {
    let w = j
        .get("w")
        .and_then(Json::as_u64)
        .ok_or_else(|| malformed("image missing 'w'"))?;
    let h = j
        .get("h")
        .and_then(Json::as_u64)
        .ok_or_else(|| malformed("image missing 'h'"))?;
    if w == 0 || h == 0 {
        return Err(malformed("image dims must be positive"));
    }
    let total = w
        .checked_mul(h)
        .filter(|&n| n <= MAX_IMAGE_PIXELS)
        .ok_or_else(|| {
            malformed(format!(
                "image dims {w}x{h} exceed the {MAX_IMAGE_PIXELS}-pixel cap"
            ))
        })?;
    let px = j
        .get("px")
        .and_then(Json::as_arr)
        .ok_or_else(|| malformed("image missing 'px'"))?;
    // analyze::allow(no-as-narrowing-in-decode): usize -> u64 widening
    // (this tree only targets 64-bit); cannot truncate.
    if px.len() as u64 != total {
        return Err(malformed(format!(
            "image has {} pixels, expected {w}x{h}={total}",
            px.len(),
        )));
    }
    let data = px
        .iter()
        .map(|p| p.as_f64().map(|f| f as f32))
        .collect::<Option<Vec<f32>>>()
        .ok_or_else(|| malformed("image 'px' entries must be numbers"))?;
    // analyze::allow(no-as-narrowing-in-decode): w*h passed the
    // MAX_IMAGE_PIXELS (2^26) checked_mul gate above, so each dim fits
    // usize with room to spare.
    Ok(Image::from_vec(w as usize, h as usize, data))
}

/// Encode an image as a v2 binary payload: a `{"w","h","bin":true}`
/// header plus a length-prefixed little-endian block — a 4-byte LE u32
/// pixel count, then count x 4 bytes of LE f32, row-major. 4 bytes per
/// pixel on the wire versus the ~17-20 a random f32 costs as a
/// shortest-round-trip JSON number, and bit-exact for every value
/// including NaN and the infinities.
pub fn encode_image_blob(img: &Image<f32>) -> (Json, Vec<u8>) {
    let px = img.to_dense();
    let mut blob = Vec::with_capacity(4 + 4 * px.len());
    // MAX_IMAGE_PIXELS (2^26) bounds the count well under u32::MAX.
    blob.extend_from_slice(&(px.len() as u32).to_le_bytes());
    for p in &px {
        blob.extend_from_slice(&p.to_le_bytes());
    }
    let header = Json::obj()
        .set("w", img.width())
        .set("h", img.height())
        .set("bin", true);
    (header, blob)
}

/// Decode an image from either encoding: a `{"bin":true}` header pairs
/// with the frame's binary block ([`encode_image_blob`]); anything else
/// falls through to the v1 JSON-array decoder ([`decode_image`]).
pub fn decode_image_any(j: &Json, blob: Option<&[u8]>) -> Result<Image<f32>, ProtocolError> {
    if j.get("bin").and_then(Json::as_bool) != Some(true) {
        return decode_image(j);
    }
    let w = j
        .get("w")
        .and_then(Json::as_u64)
        .ok_or_else(|| malformed("image missing 'w'"))?;
    let h = j
        .get("h")
        .and_then(Json::as_u64)
        .ok_or_else(|| malformed("image missing 'h'"))?;
    if w == 0 || h == 0 {
        return Err(malformed("image dims must be positive"));
    }
    let total = w
        .checked_mul(h)
        .filter(|&n| n <= MAX_IMAGE_PIXELS)
        .ok_or_else(|| {
            malformed(format!(
                "image dims {w}x{h} exceed the {MAX_IMAGE_PIXELS}-pixel cap"
            ))
        })?;
    let blob = blob.ok_or_else(|| malformed("binary image with no payload block"))?;
    if blob.len() < 4 {
        return Err(malformed("binary image block shorter than its count prefix"));
    }
    // analyze::allow(no-panic-on-wire): blob.len() >= 4 checked above.
    // analyze::allow(no-as-narrowing-in-decode): u32 -> u64 widening.
    let count = u32::from_le_bytes([blob[0], blob[1], blob[2], blob[3]]) as u64;
    // analyze::allow(no-as-narrowing-in-decode): usize -> u64 widening.
    if count != total || blob.len() as u64 != 4 + 4 * total {
        return Err(malformed(format!(
            "binary image block carries {count} pixels in {} bytes, expected {w}x{h}={total}",
            blob.len(),
        )));
    }
    // analyze::allow(no-panic-on-wire): 4 <= blob.len() checked above,
    // so the open range cannot overrun.
    let data = blob[4..]
        .chunks_exact(4)
        // analyze::allow(no-panic-on-wire): chunks_exact(4) yields
        // exactly 4-byte chunks; indexes 0..=3 are always in bounds.
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    // analyze::allow(no-as-narrowing-in-decode): w*h passed the
    // MAX_IMAGE_PIXELS (2^26) checked_mul gate above, so each dim fits
    // usize with room to spare.
    Ok(Image::from_vec(w as usize, h as usize, data))
}

/// Encode a submit request with a v1 JSON-array image.
pub fn encode_submit(req: &Request) -> Json {
    submit_qos(req).set("image", encode_image(&req.image))
}

/// Encode a submit request with the image as a v2 binary block. The
/// returned blob must travel as the frame's `payload_bytes` block.
pub fn encode_submit_blob(req: &Request) -> (Json, Vec<u8>) {
    let (img, blob) = encode_image_blob(&req.image);
    (submit_qos(req).set("image", img), blob)
}

/// The non-image submit fields (kernel, scale, QoS) shared by both
/// encodings.
fn submit_qos(req: &Request) -> Json {
    let j = Json::obj()
        .set("kernel", req.kernel.label())
        .set("scale", req.scale)
        .set("priority", req.priority.label());
    match req.deadline {
        Some(d) => j.set("deadline_ms", d.as_secs_f64() * 1e3),
        None => j,
    }
}

/// Decode what [`encode_submit`] wrote back into a [`Request`].
pub fn decode_submit(j: &Json) -> Result<Request, ProtocolError> {
    decode_submit_with(j, None)
}

/// Decode a submit payload whose image may live in the frame's binary
/// block ([`encode_submit_blob`]) or inline as a v1 JSON array.
pub fn decode_submit_with(j: &Json, blob: Option<&[u8]>) -> Result<Request, ProtocolError> {
    let kernel_s = j
        .get("kernel")
        .and_then(Json::as_str)
        .ok_or_else(|| malformed("submit missing 'kernel'"))?;
    let kernel = Interpolator::parse(kernel_s)
        .ok_or_else(|| malformed(format!("unknown kernel '{kernel_s}'")))?;
    let scale64 = j
        .get("scale")
        .and_then(Json::as_u64)
        .ok_or_else(|| malformed("submit missing 'scale'"))?;
    let scale = u32::try_from(scale64)
        .map_err(|_| malformed(format!("scale {scale64} does not fit in u32")))?;
    let image = decode_image_any(
        j.get("image")
            .ok_or_else(|| malformed("submit missing 'image'"))?,
        blob,
    )?;
    let mut req = Request::new(kernel, image, scale);
    if let Some(p) = j.get("priority").and_then(Json::as_str) {
        req = req.priority(parse_priority(p)?);
    }
    if let Some(ms) = j.get("deadline_ms").and_then(Json::as_f64) {
        req = req.deadline(duration_from_ms(ms, "deadline_ms")?);
    }
    Ok(req)
}

fn parse_priority(s: &str) -> Result<Priority, ProtocolError> {
    Priority::ALL
        .iter()
        .copied()
        .find(|p| p.label() == s)
        .ok_or_else(|| malformed(format!("unknown priority '{s}'")))
}

/// Encode a routing key (`{"kernel":...,"src":[h,w],"scale":N}`).
pub fn encode_key(key: &RequestKey) -> Json {
    Json::obj()
        .set("kernel", key.kernel.label())
        .set("src", vec![key.src.0, key.src.1])
        .set("scale", key.scale)
}

/// Encode a tile policy: `"portable"`, `{"fixed":"WxH"}`, or
/// `{"per_device":<TuningOutcome>}`.
pub fn encode_policy(p: &TilePolicy) -> Json {
    match p {
        TilePolicy::PortableFallback => Json::Str("portable".into()),
        TilePolicy::Fixed(t) => Json::obj().set("fixed", t.label()),
        TilePolicy::PerDevice(outcome) => Json::obj().set("per_device", outcome.to_json()),
    }
}

/// Decode what [`encode_policy`] wrote.
pub fn decode_policy(j: &Json) -> Result<TilePolicy, ProtocolError> {
    if let Some(s) = j.as_str() {
        return match s {
            "portable" => Ok(TilePolicy::PortableFallback),
            other => Err(malformed(format!("unknown policy '{other}'"))),
        };
    }
    if let Some(t) = j.get("fixed") {
        let label = t
            .as_str()
            .ok_or_else(|| malformed("'fixed' policy must name a WxH tile"))?;
        let tile: TileDim = label
            .parse()
            .map_err(|e: String| malformed(format!("'fixed' policy: {e}")))?;
        return Ok(TilePolicy::Fixed(tile));
    }
    if let Some(o) = j.get("per_device") {
        let outcome = crate::autotuner::TuningOutcome::from_json(o)
            .map_err(|e| malformed(format!("'per_device' policy: {e:#}")))?;
        return Ok(TilePolicy::PerDevice(outcome));
    }
    Err(malformed(
        "policy must be \"portable\", {\"fixed\":...}, or {\"per_device\":...}",
    ))
}

/// Parse a drain mode name.
pub fn parse_drain_mode(s: &str) -> Result<DrainMode, ProtocolError> {
    match s {
        "graceful" => Ok(DrainMode::Graceful),
        "immediate" => Ok(DrainMode::Immediate),
        other => Err(malformed(format!(
            "unknown drain mode '{other}' (graceful|immediate)"
        ))),
    }
}

pub fn drain_mode_name(m: DrainMode) -> &'static str {
    match m {
        DrainMode::Graceful => "graceful",
        DrainMode::Immediate => "immediate",
    }
}

// ------------------------------------------------ topology snapshot --

/// One fleet member as seen over the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct MemberDesc {
    pub id: u64,
    pub label: String,
    /// Registry id of the member's device (`None` = anonymous backend).
    pub device: Option<String>,
    pub tile: Option<TileDim>,
    pub batch_max: u64,
    pub draining: bool,
    pub admitted: u64,
    pub completed: u64,
    pub inflight: u64,
}

/// An epoch-stamped remote topology snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct TopologyDesc {
    pub epoch: u64,
    pub members: Vec<MemberDesc>,
}

impl TopologyDesc {
    /// Snapshot a live [`TopologyView`] — the one wire-independent
    /// topology shape both the in-process and remote control planes
    /// hand out (see [`crate::ops::ControlOps`]).
    pub fn of(t: &TopologyView) -> TopologyDesc {
        TopologyDesc {
            epoch: t.epoch,
            members: t
                .members
                .iter()
                .map(|m| MemberDesc {
                    id: m.id,
                    label: m.label.to_string(),
                    device: m.device.as_ref().map(|d| d.id.clone()),
                    tile: m.tile_pref,
                    // analyze::allow(no-as-narrowing-in-decode): encoding
                    // a local snapshot; usize -> u64 widening.
                    batch_max: m.batch_max as u64,
                    draining: m.draining,
                    admitted: m.stats.admitted.get(),
                    completed: m.stats.completed.get(),
                    inflight: m.stats.inflight(),
                })
                .collect(),
        }
    }

    /// True when no member can accept new work (empty fleet or every
    /// member draining) — the shard tier routes around such fleets.
    pub fn is_draining(&self) -> bool {
        self.members.iter().all(|m| m.draining)
    }

    pub fn to_json(&self) -> Json {
        let members: Vec<Json> = self
            .members
            .iter()
            .map(|m| {
                let j = Json::obj()
                    .set("id", m.id)
                    .set("label", m.label.as_str())
                    .set(
                        "tile",
                        match m.tile {
                            Some(t) => Json::Str(t.label()),
                            None => Json::Null,
                        },
                    )
                    .set("batch_max", m.batch_max)
                    .set("draining", m.draining)
                    .set("admitted", m.admitted)
                    .set("completed", m.completed)
                    .set("inflight", m.inflight);
                match &m.device {
                    Some(d) => j.set("device", d.as_str()),
                    None => j,
                }
            })
            .collect();
        Json::obj()
            .set("epoch", self.epoch)
            .set("members", Json::Arr(members))
    }

    pub fn from_json(j: &Json) -> Result<TopologyDesc, ProtocolError> {
        let epoch = j
            .get("epoch")
            .and_then(Json::as_u64)
            .ok_or_else(|| malformed("topology missing 'epoch'"))?;
        let arr = j
            .get("members")
            .and_then(Json::as_arr)
            .ok_or_else(|| malformed("topology missing 'members'"))?;
        let members = arr
            .iter()
            .map(|m| {
                let field = |k: &str| {
                    m.get(k)
                        .and_then(Json::as_u64)
                        .ok_or_else(|| malformed(format!("member missing '{k}'")))
                };
                let tile = match m.get("tile") {
                    None | Some(Json::Null) => None,
                    Some(t) => {
                        let s = t
                            .as_str()
                            .ok_or_else(|| malformed("member 'tile' must be a string"))?;
                        Some(
                            s.parse::<TileDim>()
                                .map_err(|e: String| malformed(format!("member tile: {e}")))?,
                        )
                    }
                };
                Ok(MemberDesc {
                    id: field("id")?,
                    label: m
                        .get("label")
                        .and_then(Json::as_str)
                        .ok_or_else(|| malformed("member missing 'label'"))?
                        .to_string(),
                    device: m
                        .get("device")
                        .and_then(Json::as_str)
                        .map(str::to_string),
                    tile,
                    batch_max: field("batch_max")?,
                    draining: m
                        .get("draining")
                        .and_then(Json::as_bool)
                        .ok_or_else(|| malformed("member missing 'draining'"))?,
                    admitted: field("admitted")?,
                    completed: field("completed")?,
                    inflight: field("inflight")?,
                })
            })
            .collect::<Result<Vec<_>, ProtocolError>>()?;
        Ok(TopologyDesc { epoch, members })
    }
}

/// Snapshot a live [`TopologyView`] into its wire form.
pub fn encode_topology(t: &TopologyView) -> Json {
    TopologyDesc::of(t).to_json()
}

// ------------------------------------------------------ stats frame --

/// [`ServingStats`] flattened for the wire: every counter, plus the
/// latency histogram reduced to count/mean/percentiles (histogram
/// buckets do not cross the wire). `merge_from` sums counters and takes
/// the conservative (max) percentile, giving the shard tier its
/// fleet-of-fleets view.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WireStats {
    pub admitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    pub shed: u64,
    pub cancelled: u64,
    pub steals: u64,
    pub stolen: u64,
    pub infeasible: u64,
    pub retunes: u64,
    pub scale_ups: u64,
    pub scale_downs: u64,
    pub migrated_batches: u64,
    pub batches: u64,
    pub batched: u64,
    pub sim_cost_ns: u64,
    pub unpriced: u64,
    pub latency_count: u64,
    pub latency_mean_us: f64,
    pub latency_p50_us: f64,
    pub latency_p99_us: f64,
    /// Sampled submit-path breakdown timings (PR 8): how many submits
    /// were sampled, and the p99 of each phase.
    pub submit_samples: u64,
    pub submit_snapshot_p99_us: f64,
    pub submit_schedule_p99_us: f64,
    pub submit_admit_p99_us: f64,
}

impl WireStats {
    pub fn of(s: &ServingStats) -> WireStats {
        WireStats {
            admitted: s.admitted.get(),
            rejected: s.rejected.get(),
            completed: s.completed.get(),
            failed: s.failed.get(),
            shed: s.shed.get(),
            cancelled: s.cancelled.get(),
            steals: s.steals.get(),
            stolen: s.stolen.get(),
            infeasible: s.infeasible.get(),
            retunes: s.retunes.get(),
            scale_ups: s.scale_ups.get(),
            scale_downs: s.scale_downs.get(),
            migrated_batches: s.migrated_batches.get(),
            batches: s.batches.get(),
            batched: s.batched.get(),
            sim_cost_ns: s.sim_cost_ns.get(),
            unpriced: s.unpriced.get(),
            latency_count: s.latency.count(),
            latency_mean_us: s.latency.mean_us(),
            latency_p50_us: s.latency.percentile_us(50.0),
            latency_p99_us: s.latency.percentile_us(99.0),
            submit_samples: s.submit_snapshot.count(),
            submit_snapshot_p99_us: s.submit_snapshot.percentile_us(99.0),
            submit_schedule_p99_us: s.submit_schedule.percentile_us(99.0),
            submit_admit_p99_us: s.submit_admit.percentile_us(99.0),
        }
    }

    /// Fold another fleet's stats into this one: counters add; the mean
    /// is sample-weighted; percentiles take the max (a conservative
    /// bound — true cross-fleet percentiles would need the buckets).
    pub fn merge_from(&mut self, o: &WireStats) {
        let n = self.latency_count + o.latency_count;
        if n > 0 {
            self.latency_mean_us = (self.latency_mean_us * self.latency_count as f64
                + o.latency_mean_us * o.latency_count as f64)
                / n as f64;
        }
        self.latency_count = n;
        self.latency_p50_us = self.latency_p50_us.max(o.latency_p50_us);
        self.latency_p99_us = self.latency_p99_us.max(o.latency_p99_us);
        self.submit_samples += o.submit_samples;
        self.submit_snapshot_p99_us = self.submit_snapshot_p99_us.max(o.submit_snapshot_p99_us);
        self.submit_schedule_p99_us = self.submit_schedule_p99_us.max(o.submit_schedule_p99_us);
        self.submit_admit_p99_us = self.submit_admit_p99_us.max(o.submit_admit_p99_us);
        self.admitted += o.admitted;
        self.rejected += o.rejected;
        self.completed += o.completed;
        self.failed += o.failed;
        self.shed += o.shed;
        self.cancelled += o.cancelled;
        self.steals += o.steals;
        self.stolen += o.stolen;
        self.infeasible += o.infeasible;
        self.retunes += o.retunes;
        self.scale_ups += o.scale_ups;
        self.scale_downs += o.scale_downs;
        self.migrated_batches += o.migrated_batches;
        self.batches += o.batches;
        self.batched += o.batched;
        self.sim_cost_ns += o.sim_cost_ns;
        self.unpriced += o.unpriced;
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("admitted", self.admitted)
            .set("rejected", self.rejected)
            .set("completed", self.completed)
            .set("failed", self.failed)
            .set("shed", self.shed)
            .set("cancelled", self.cancelled)
            .set("steals", self.steals)
            .set("stolen", self.stolen)
            .set("infeasible", self.infeasible)
            .set("retunes", self.retunes)
            .set("scale_ups", self.scale_ups)
            .set("scale_downs", self.scale_downs)
            .set("migrated_batches", self.migrated_batches)
            .set("batches", self.batches)
            .set("batched", self.batched)
            .set("sim_cost_ns", self.sim_cost_ns)
            .set("unpriced", self.unpriced)
            .set("latency_count", self.latency_count)
            .set("latency_mean_us", self.latency_mean_us)
            .set("latency_p50_us", self.latency_p50_us)
            .set("latency_p99_us", self.latency_p99_us)
            .set("submit_samples", self.submit_samples)
            .set("submit_snapshot_p99_us", self.submit_snapshot_p99_us)
            .set("submit_schedule_p99_us", self.submit_schedule_p99_us)
            .set("submit_admit_p99_us", self.submit_admit_p99_us)
    }

    pub fn from_json(j: &Json) -> Result<WireStats, ProtocolError> {
        let n = |k: &str| {
            j.get(k)
                .and_then(Json::as_u64)
                .ok_or_else(|| malformed(format!("stats missing '{k}'")))
        };
        let f = |k: &str| {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| malformed(format!("stats missing '{k}'")))
        };
        Ok(WireStats {
            admitted: n("admitted")?,
            rejected: n("rejected")?,
            completed: n("completed")?,
            failed: n("failed")?,
            shed: n("shed")?,
            cancelled: n("cancelled")?,
            steals: n("steals")?,
            stolen: n("stolen")?,
            infeasible: n("infeasible")?,
            retunes: n("retunes")?,
            // PR 7 additions: absent on frames from an older peer, so
            // they default to 0 instead of failing the whole stats read.
            scale_ups: j.get("scale_ups").and_then(Json::as_u64).unwrap_or(0),
            scale_downs: j.get("scale_downs").and_then(Json::as_u64).unwrap_or(0),
            migrated_batches: j
                .get("migrated_batches")
                .and_then(Json::as_u64)
                .unwrap_or(0),
            batches: n("batches")?,
            batched: n("batched")?,
            sim_cost_ns: n("sim_cost_ns")?,
            unpriced: n("unpriced")?,
            latency_count: n("latency_count")?,
            latency_mean_us: f("latency_mean_us")?,
            latency_p50_us: f("latency_p50_us")?,
            latency_p99_us: f("latency_p99_us")?,
            // PR 8 additions: same older-peer tolerance as above.
            submit_samples: j.get("submit_samples").and_then(Json::as_u64).unwrap_or(0),
            submit_snapshot_p99_us: j
                .get("submit_snapshot_p99_us")
                .and_then(Json::as_f64)
                .unwrap_or(0.0),
            submit_schedule_p99_us: j
                .get("submit_schedule_p99_us")
                .and_then(Json::as_f64)
                .unwrap_or(0.0),
            submit_admit_p99_us: j
                .get("submit_admit_p99_us")
                .and_then(Json::as_f64)
                .unwrap_or(0.0),
        })
    }

    /// One-line summary for reports.
    pub fn summary(&self) -> String {
        format!(
            "admitted={} rejected={} completed={} failed={} shed={} cancelled={} \
             latency n={} mean={:.0}us p50={:.0}us p99={:.0}us",
            self.admitted,
            self.rejected,
            self.completed,
            self.failed,
            self.shed,
            self.cancelled,
            self.latency_count,
            self.latency_mean_us,
            self.latency_p50_us,
            self.latency_p99_us,
        )
    }
}

// ------------------------------------------------- autoscaler frame --

/// An [`AutoscalerView`] as seen over the wire: the `ok` payload of
/// both the `autoscaler` and `set_autoscaler` verbs (the latter echoes
/// the post-update state so the caller needs no second round trip).
#[derive(Debug, Clone, PartialEq)]
pub struct AutoscalerDesc {
    pub enabled: bool,
    pub low_queue: f64,
    pub high_queue: f64,
    pub high_p99_us: u64,
    pub cooldown_ticks: u64,
    pub poll_ms: u64,
    pub min_members: u64,
    pub max_members: u64,
    pub standby_free: u64,
    pub ticks: u64,
    pub scale_ups: u64,
    pub scale_downs: u64,
    pub holds: u64,
    pub errors: u64,
}

impl AutoscalerDesc {
    pub fn of(v: &AutoscalerView) -> AutoscalerDesc {
        AutoscalerDesc {
            enabled: v.enabled,
            low_queue: v.low_queue,
            high_queue: v.high_queue,
            high_p99_us: v.high_p99_us,
            // analyze::allow(no-as-narrowing-in-decode): encoding a local
            // snapshot; all four casts are usize -> u64 widenings.
            cooldown_ticks: v.cooldown_ticks as u64,
            poll_ms: v.poll_ms,
            // analyze::allow(no-as-narrowing-in-decode): usize -> u64.
            min_members: v.min_members as u64,
            // analyze::allow(no-as-narrowing-in-decode): usize -> u64.
            max_members: v.max_members as u64,
            // analyze::allow(no-as-narrowing-in-decode): usize -> u64.
            standby_free: v.standby_free as u64,
            ticks: v.ticks,
            scale_ups: v.scale_ups,
            scale_downs: v.scale_downs,
            holds: v.holds,
            errors: v.errors,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("enabled", self.enabled)
            .set("low_queue", self.low_queue)
            .set("high_queue", self.high_queue)
            .set("high_p99_us", self.high_p99_us)
            .set("cooldown_ticks", self.cooldown_ticks)
            .set("poll_ms", self.poll_ms)
            .set("min_members", self.min_members)
            .set("max_members", self.max_members)
            .set("standby_free", self.standby_free)
            .set("ticks", self.ticks)
            .set("scale_ups", self.scale_ups)
            .set("scale_downs", self.scale_downs)
            .set("holds", self.holds)
            .set("errors", self.errors)
    }

    pub fn from_json(j: &Json) -> Result<AutoscalerDesc, ProtocolError> {
        let n = |k: &str| {
            j.get(k)
                .and_then(Json::as_u64)
                .ok_or_else(|| malformed(format!("autoscaler missing '{k}'")))
        };
        let f = |k: &str| {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| malformed(format!("autoscaler missing '{k}'")))
        };
        Ok(AutoscalerDesc {
            enabled: j
                .get("enabled")
                .and_then(Json::as_bool)
                .ok_or_else(|| malformed("autoscaler missing 'enabled'"))?,
            low_queue: f("low_queue")?,
            high_queue: f("high_queue")?,
            high_p99_us: n("high_p99_us")?,
            cooldown_ticks: n("cooldown_ticks")?,
            poll_ms: n("poll_ms")?,
            min_members: n("min_members")?,
            max_members: n("max_members")?,
            standby_free: n("standby_free")?,
            ticks: n("ticks")?,
            scale_ups: n("scale_ups")?,
            scale_downs: n("scale_downs")?,
            holds: n("holds")?,
            errors: n("errors")?,
        })
    }

    /// One-line status, mirroring [`AutoscalerView::summary`].
    pub fn summary(&self) -> String {
        format!(
            "autoscaler {} | members {}..={} standby_free={} | low={} high={} \
             cooldown={} poll={}ms | ticks={} ups={} downs={} holds={} errors={}",
            if self.enabled { "enabled" } else { "disabled" },
            self.min_members,
            self.max_members,
            self.standby_free,
            self.low_queue,
            self.high_queue,
            self.cooldown_ticks,
            self.poll_ms,
            self.ticks,
            self.scale_ups,
            self.scale_downs,
            self.holds,
            self.errors,
        )
    }
}

/// Encode a partial [`AutoscalerUpdate`] as the `set_autoscaler`
/// request payload — only the fields being changed appear on the wire.
pub fn encode_autoscaler_update(u: &AutoscalerUpdate) -> Json {
    let mut j = Json::obj();
    if let Some(e) = u.enabled {
        j = j.set("enabled", e);
    }
    if let Some(v) = u.low_queue {
        j = j.set("low_queue", v);
    }
    if let Some(v) = u.high_queue {
        j = j.set("high_queue", v);
    }
    if let Some(v) = u.high_p99_us {
        j = j.set("high_p99_us", v);
    }
    if let Some(v) = u.cooldown_ticks {
        j = j.set("cooldown_ticks", v as u64);
    }
    j
}

/// Decode what [`encode_autoscaler_update`] wrote. Absent fields stay
/// `None` (unchanged); present fields must have the right type.
pub fn decode_autoscaler_update(j: &Json) -> Result<AutoscalerUpdate, ProtocolError> {
    let mut u = AutoscalerUpdate::default();
    if let Some(e) = j.get("enabled") {
        u.enabled = Some(
            e.as_bool()
                .ok_or_else(|| malformed("'enabled' must be a bool"))?,
        );
    }
    for (key, slot) in [
        ("low_queue", &mut u.low_queue),
        ("high_queue", &mut u.high_queue),
    ] {
        if let Some(v) = j.get(key) {
            *slot = Some(
                v.as_f64()
                    .ok_or_else(|| malformed(format!("'{key}' must be a number")))?,
            );
        }
    }
    if let Some(v) = j.get("high_p99_us") {
        u.high_p99_us = Some(
            v.as_u64()
                .ok_or_else(|| malformed("'high_p99_us' must be a non-negative integer"))?,
        );
    }
    if let Some(v) = j.get("cooldown_ticks") {
        let raw = v
            .as_u64()
            .ok_or_else(|| malformed("'cooldown_ticks' must be a non-negative integer"))?;
        let ticks = u32::try_from(raw)
            .map_err(|_| malformed(format!("cooldown_ticks {raw} does not fit in u32")))?;
        u.cooldown_ticks = Some(ticks);
    }
    Ok(u)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::generate;
    use std::io::BufReader;

    #[test]
    fn request_frame_round_trips_every_verb() {
        for (i, verb) in Verb::ALL.into_iter().enumerate() {
            let f = RequestFrame::new(i as u64, verb, Json::obj().set("x", 1u64));
            let line = f.to_line();
            assert!(line.ends_with('\n'));
            assert_eq!(RequestFrame::parse(&line).unwrap(), f);
        }
    }

    #[test]
    fn error_frame_round_trips_every_kind() {
        for (i, kind) in WireErrorKind::ALL.into_iter().enumerate() {
            let f = ResponseFrame::err(i as u64, WireError::new(kind, "boom"));
            assert_eq!(ResponseFrame::parse(&f.to_line()).unwrap(), f);
        }
    }

    #[test]
    fn submit_error_round_trips() {
        for e in [
            SubmitError::Saturated,
            SubmitError::Unsupported,
            SubmitError::DeadlineExceeded,
            SubmitError::Infeasible,
            SubmitError::ShuttingDown,
        ] {
            let msg = e.to_string();
            let w = WireError::from_submit(&e);
            assert_eq!(w.msg, msg);
            assert_eq!(w.to_submit(), Some(e));
        }
        assert_eq!(
            WireError::new(WireErrorKind::Failed, "x").to_submit(),
            None
        );
    }

    #[test]
    fn version_mismatch_is_typed() {
        let line = "{\"v\":3,\"id\":1,\"verb\":\"stats\",\"payload\":{}}";
        assert_eq!(
            RequestFrame::parse(line),
            Err(ProtocolError::Version { got: 3 })
        );
        // Both live revisions parse.
        for v in [1, 2] {
            let line = format!("{{\"v\":{v},\"id\":1,\"verb\":\"stats\",\"payload\":{{}}}}");
            assert_eq!(RequestFrame::parse(&line).unwrap().verb, Verb::Stats);
        }
    }

    #[test]
    fn hello_negotiation_pins_the_smaller_version() {
        assert_eq!(negotiate(PROTOCOL_V2, PROTOCOL_V2), PROTOCOL_V2);
        assert_eq!(negotiate(PROTOCOL_V2, PROTOCOL_VERSION), PROTOCOL_VERSION);
        assert_eq!(negotiate(PROTOCOL_VERSION, PROTOCOL_V2), PROTOCOL_VERSION);
        // A nonsense max of 0 still floors at the baseline.
        assert_eq!(negotiate(0, PROTOCOL_V2), PROTOCOL_VERSION);
        assert_eq!(decode_hello_max(&encode_hello(2)), 2);
        assert_eq!(decode_hello_max(&Json::obj()), PROTOCOL_VERSION);
        assert_eq!(
            decode_hello_max(&Json::obj().set("max", "two")),
            PROTOCOL_VERSION
        );
    }

    #[test]
    fn payload_encoding_names_round_trip() {
        for enc in [PayloadEncoding::Json, PayloadEncoding::Binary] {
            assert_eq!(PayloadEncoding::parse(enc.name()), Some(enc));
        }
        assert_eq!(PayloadEncoding::parse("msgpack"), None);
    }

    #[test]
    fn image_blob_round_trips_bit_exactly() {
        let mut img = generate::test_scene(13, 7, 42);
        // Values JSON cannot carry at all must survive the blob.
        img.set(0, 0, f32::NAN);
        img.set(1, 0, f32::INFINITY);
        img.set(2, 0, f32::NEG_INFINITY);
        let (header, blob) = encode_image_blob(&img);
        assert_eq!(blob.len(), 4 + 4 * 13 * 7);
        let back = decode_image_any(&header, Some(&blob)).unwrap();
        assert_eq!(back.width(), 13);
        assert_eq!(back.height(), 7);
        let (a, b) = (img.to_dense(), back.to_dense());
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.to_bits(), y.to_bits(), "pixels must be bit-identical");
        }
        // A non-binary header falls through to the v1 decoder.
        let plain = generate::test_scene(5, 3, 9);
        let v1 = decode_image_any(&encode_image(&plain), None).unwrap();
        assert_eq!(plain.max_abs_diff(&v1), 0.0);
    }

    #[test]
    fn image_blob_rejects_corrupt_blocks() {
        let img = generate::gradient(4, 4);
        let (header, blob) = encode_image_blob(&img);
        // Missing block, truncated block, short prefix, and a count
        // prefix that disagrees with the dims are all typed errors.
        assert!(decode_image_any(&header, None).is_err());
        assert!(decode_image_any(&header, Some(&blob[..blob.len() - 1])).is_err());
        assert!(decode_image_any(&header, Some(&blob[..2])).is_err());
        let mut lying = blob.clone();
        lying[0] ^= 1;
        assert!(decode_image_any(&header, Some(&lying)).is_err());
        // Hostile dims are rejected before the block is even consulted.
        let huge = Json::obj()
            .set("w", (MAX_IMAGE_PIXELS + 1) as f64)
            .set("h", 1u64)
            .set("bin", true);
        assert!(decode_image_any(&huge, Some(&blob)).is_err());
    }

    #[test]
    fn submit_blob_round_trips_through_a_v2_frame() {
        let req = Request::new(Interpolator::Bilinear, generate::test_scene(16, 9, 7), 2)
            .priority(Priority::Batch)
            .deadline(Duration::from_millis(125));
        let (payload, blob) = encode_submit_blob(&req);
        let frame = RequestFrame::new(9, Verb::Submit, payload);
        let wire = frame.to_wire(PROTOCOL_V2, Some(&blob));
        // Replay the bytes the way a server reader would.
        let mut r = BufReader::new(&wire[..]);
        let line = read_frame_line(&mut r, DEFAULT_MAX_LINE_BYTES)
            .unwrap()
            .unwrap();
        let j = Json::parse(line.trim_end()).unwrap();
        let extra = frame_extra_bytes(&j).unwrap();
        assert_eq!(extra, blob.len());
        let got = read_payload(&mut r, extra, DEFAULT_MAX_LINE_BYTES).unwrap();
        let parsed = RequestFrame::from_json(&j).unwrap();
        assert_eq!(parsed.id, 9);
        let back = decode_submit_with(&parsed.payload, Some(&got)).unwrap();
        assert_eq!(back.kernel, Interpolator::Bilinear);
        assert_eq!(back.scale, 2);
        assert_eq!(back.priority, Priority::Batch);
        assert_eq!(back.deadline, Some(Duration::from_millis(125)));
        assert_eq!(back.image.max_abs_diff(&req.image), 0.0);
        // A v1 line has no block and stays pure JSON.
        assert_eq!(frame_extra_bytes(&Json::parse(frame.to_line().trim_end()).unwrap()).unwrap(), 0);
    }

    #[test]
    fn read_payload_enforces_caps_and_truncation() {
        let bytes = [7u8; 32];
        let mut r = BufReader::new(&bytes[..]);
        assert_eq!(read_payload(&mut r, 32, 64).unwrap(), vec![7u8; 32]);
        let mut r = BufReader::new(&bytes[..]);
        assert_eq!(
            read_payload(&mut r, 65, 64),
            Err(ProtocolError::Oversized { limit: 64 })
        );
        // EOF inside the block is truncation, not a short read.
        let mut r = BufReader::new(&bytes[..]);
        assert_eq!(
            read_payload(&mut r, 33, 64),
            Err(ProtocolError::Truncated)
        );
        // A zero-length block is legal and consumes nothing.
        let mut r = BufReader::new(&bytes[..]);
        assert_eq!(read_payload(&mut r, 0, 64).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn malformed_frames_error_not_panic() {
        for bad in [
            "",
            "{",
            "[1,2]",
            "{\"v\":1}",
            "{\"v\":1,\"id\":1}",
            "{\"v\":1,\"id\":1,\"verb\":\"warp\"}",
            "{\"v\":1,\"id\":-3,\"verb\":\"stats\"}",
        ] {
            assert!(
                matches!(
                    RequestFrame::parse(bad),
                    Err(ProtocolError::Malformed(_))
                ),
                "{bad:?} should be malformed"
            );
        }
        assert!(ResponseFrame::parse("{\"v\":1,\"id\":1}").is_err());
        assert!(ResponseFrame::parse(
            "{\"v\":1,\"id\":1,\"ok\":{},\"err\":{\"kind\":\"failed\"}}"
        )
        .is_err());
    }

    #[test]
    fn oversized_line_is_typed() {
        let long = format!("{}\n", "x".repeat(64));
        let mut r = BufReader::new(long.as_bytes());
        assert_eq!(
            read_frame_line(&mut r, 16),
            Err(ProtocolError::Oversized { limit: 16 })
        );
    }

    #[test]
    fn truncated_stream_is_typed() {
        let mut r = BufReader::new(&b"{\"v\":1,\"id\":1"[..]);
        assert_eq!(read_frame_line(&mut r, 1024), Err(ProtocolError::Truncated));
        let mut empty = BufReader::new(&b""[..]);
        assert_eq!(read_frame_line(&mut empty, 1024), Ok(None));
    }

    #[test]
    fn frame_reader_splits_lines() {
        let two = "{\"v\":1,\"id\":1,\"verb\":\"stats\",\"payload\":{}}\n\
                   {\"v\":1,\"id\":2,\"verb\":\"topology\",\"payload\":{}}\n";
        let mut r = BufReader::new(two.as_bytes());
        let a = read_frame_line(&mut r, 4096).unwrap().unwrap();
        assert_eq!(RequestFrame::parse(&a).unwrap().id, 1);
        let b = read_frame_line(&mut r, 4096).unwrap().unwrap();
        assert_eq!(RequestFrame::parse(&b).unwrap().verb, Verb::Topology);
        assert_eq!(read_frame_line(&mut r, 4096), Ok(None));
    }

    #[test]
    fn image_round_trips_exactly() {
        let img = generate::test_scene(13, 7, 42);
        let j = encode_image(&img);
        let back = decode_image(&j).unwrap();
        assert_eq!(back.width(), 13);
        assert_eq!(back.height(), 7);
        assert_eq!(img.max_abs_diff(&back), 0.0, "f32 pixels must be exact");
    }

    #[test]
    fn image_rejects_bad_payloads() {
        assert!(decode_image(&Json::obj()).is_err());
        let short = Json::obj()
            .set("w", 2u64)
            .set("h", 2u64)
            .set("px", vec![1.0f64]);
        assert!(decode_image(&short).is_err());
        let zero = Json::obj().set("w", 0u64).set("h", 2u64).set(
            "px",
            Vec::<f64>::new(),
        );
        assert!(decode_image(&zero).is_err());
    }

    #[test]
    fn image_rejects_overflowing_dims() {
        // w*h wraps to 0 in u64 — must not pass the px.len() check.
        let wrap = Json::obj()
            .set("w", 4294967296.0)
            .set("h", 4294967296.0)
            .set("px", Vec::<f64>::new());
        assert!(matches!(
            decode_image(&wrap),
            Err(ProtocolError::Malformed(_))
        ));
        // A finite product past the pixel cap is rejected even with a
        // matching (hypothetical) px array.
        let huge = Json::obj()
            .set("w", (MAX_IMAGE_PIXELS + 1) as f64)
            .set("h", 1u64)
            .set("px", Vec::<f64>::new());
        assert!(matches!(
            decode_image(&huge),
            Err(ProtocolError::Malformed(_))
        ));
        // Dims past u64 saturate through as_u64 and still overflow out.
        let sat = Json::obj()
            .set("w", 1e300)
            .set("h", 1e300)
            .set("px", Vec::<f64>::new());
        assert!(decode_image(&sat).is_err());
    }

    #[test]
    fn submit_rejects_hostile_qos_fields() {
        let base = || {
            Json::obj()
                .set("kernel", "nearest")
                .set("scale", 2u64)
                .set("image", encode_image(&generate::gradient(4, 4)))
        };
        // A huge finite deadline must be a typed error, not a
        // Duration::from_secs_f64 panic.
        for bad_ms in [1e300, MAX_DURATION_MS * 2.0, -1.0, f64::INFINITY, f64::NAN] {
            let j = base().set("deadline_ms", bad_ms);
            assert!(
                matches!(decode_submit(&j), Err(ProtocolError::Malformed(_))),
                "deadline_ms {bad_ms} should be rejected"
            );
        }
        // scale that does not fit u32 is rejected, never truncated.
        let j = base().set("scale", 4294967298.0);
        assert!(matches!(
            decode_submit(&j),
            Err(ProtocolError::Malformed(_))
        ));
        let j = base().set("scale", 1e300);
        assert!(decode_submit(&j).is_err());
    }

    #[test]
    fn duration_from_ms_bounds() {
        assert_eq!(
            duration_from_ms(250.0, "t").unwrap(),
            Duration::from_millis(250)
        );
        assert_eq!(duration_from_ms(0.0, "t").unwrap(), Duration::ZERO);
        assert!(duration_from_ms(MAX_DURATION_MS, "t").is_ok());
        for bad in [-0.5, f64::NAN, f64::INFINITY, MAX_DURATION_MS + 1.0, 1e300] {
            assert!(duration_from_ms(bad, "t").is_err(), "{bad} should fail");
        }
    }

    #[test]
    fn submit_round_trips_qos() {
        let req = Request::new(Interpolator::Bilinear, generate::gradient(8, 8), 2)
            .priority(Priority::Batch)
            .deadline(Duration::from_millis(250));
        let j = encode_submit(&req);
        let back = decode_submit(&j).unwrap();
        assert_eq!(back.kernel, Interpolator::Bilinear);
        assert_eq!(back.scale, 2);
        assert_eq!(back.priority, Priority::Batch);
        assert_eq!(back.deadline, Some(Duration::from_millis(250)));
        assert_eq!(back.key(), req.key());
        // defaults apply when QoS fields are absent
        let bare = decode_submit(
            &Json::obj()
                .set("kernel", "nearest")
                .set("scale", 3u64)
                .set("image", encode_image(&generate::gradient(4, 4))),
        )
        .unwrap();
        assert_eq!(bare.priority, Priority::Interactive);
        assert_eq!(bare.deadline, None);
    }

    #[test]
    fn policy_round_trips() {
        let p = decode_policy(&encode_policy(&TilePolicy::PortableFallback)).unwrap();
        assert!(matches!(p, TilePolicy::PortableFallback));
        let p = decode_policy(&encode_policy(&TilePolicy::Fixed(TileDim::new(32, 4)))).unwrap();
        match p {
            TilePolicy::Fixed(t) => assert_eq!(t, TileDim::new(32, 4)),
            other => panic!("expected fixed, got {other:?}"),
        }
        assert!(decode_policy(&Json::Str("yolo".into())).is_err());
        assert!(decode_policy(&Json::obj()).is_err());
    }

    #[test]
    fn topology_round_trips() {
        let t = TopologyDesc {
            epoch: 9,
            members: vec![
                MemberDesc {
                    id: 0,
                    label: "gtx260".into(),
                    device: Some("gtx260".into()),
                    tile: Some(TileDim::new(16, 8)),
                    batch_max: 8,
                    draining: false,
                    admitted: 10,
                    completed: 9,
                    inflight: 1,
                },
                MemberDesc {
                    id: 1,
                    label: "dev1".into(),
                    device: None,
                    tile: None,
                    batch_max: 4,
                    draining: true,
                    admitted: 0,
                    completed: 0,
                    inflight: 0,
                },
            ],
        };
        let back = TopologyDesc::from_json(&t.to_json()).unwrap();
        assert_eq!(back, t);
        assert!(!back.is_draining());
        let all_draining = TopologyDesc {
            epoch: 1,
            members: vec![MemberDesc {
                draining: true,
                ..t.members[1].clone()
            }],
        };
        assert!(all_draining.is_draining());
    }

    #[test]
    fn stats_round_trip_and_merge() {
        let s = ServingStats::new();
        s.admitted.add(5);
        s.completed.add(4);
        s.record_latency(Priority::Interactive, Duration::from_micros(100));
        let w = WireStats::of(&s);
        let back = WireStats::from_json(&w.to_json()).unwrap();
        assert_eq!(back, w);
        let mut merged = back.clone();
        merged.merge_from(&w);
        assert_eq!(merged.admitted, 10);
        assert_eq!(merged.completed, 8);
        assert_eq!(merged.latency_count, 2);
        assert!(merged.summary().contains("admitted=10"));
    }

    #[test]
    fn stats_carry_scale_and_migration_counters() {
        let s = ServingStats::new();
        s.scale_ups.add(3);
        s.scale_downs.add(2);
        s.migrated_batches.add(7);
        let w = WireStats::of(&s);
        let back = WireStats::from_json(&w.to_json()).unwrap();
        assert_eq!(back, w);
        let mut merged = back.clone();
        merged.merge_from(&w);
        assert_eq!(merged.scale_ups, 6);
        assert_eq!(merged.scale_downs, 4);
        assert_eq!(merged.migrated_batches, 14);
    }

    #[test]
    fn stats_from_an_older_peer_default_the_new_counters() {
        // A pre-autoscaler peer never writes the PR 7 counters; the
        // frame must still decode, with those counters at zero.
        let mut w = WireStats::of(&ServingStats::new());
        w.admitted = 5;
        w.scale_ups = 9;
        let old = match w.to_json() {
            Json::Obj(pairs) => Json::Obj(
                pairs
                    .into_iter()
                    .filter(|(k, _)| {
                        !matches!(
                            k.as_str(),
                            "scale_ups" | "scale_downs" | "migrated_batches"
                        )
                    })
                    .collect(),
            ),
            other => other,
        };
        let back = WireStats::from_json(&old).unwrap();
        assert_eq!(back.admitted, 5);
        assert_eq!(back.scale_ups, 0);
        assert_eq!(back.scale_downs, 0);
        assert_eq!(back.migrated_batches, 0);
    }

    #[test]
    fn autoscaler_desc_round_trips() {
        let d = AutoscalerDesc {
            enabled: true,
            low_queue: 1.5,
            high_queue: 8.0,
            high_p99_us: 250_000,
            cooldown_ticks: 5,
            poll_ms: 100,
            min_members: 1,
            max_members: 3,
            standby_free: 2,
            ticks: 40,
            scale_ups: 2,
            scale_downs: 1,
            holds: 37,
            errors: 0,
        };
        let back = AutoscalerDesc::from_json(&d.to_json()).unwrap();
        assert_eq!(back, d);
        let s = back.summary();
        assert!(s.contains("autoscaler enabled"), "{s}");
        assert!(s.contains("members 1..=3"), "{s}");
        assert!(AutoscalerDesc::from_json(&Json::obj()).is_err());
    }

    #[test]
    fn autoscaler_update_round_trips_sparsely() {
        // Full update survives.
        let full = AutoscalerUpdate {
            enabled: Some(false),
            low_queue: Some(0.5),
            high_queue: Some(12.0),
            high_p99_us: Some(50_000),
            cooldown_ticks: Some(9),
        };
        let j = encode_autoscaler_update(&full);
        assert_eq!(decode_autoscaler_update(&j).unwrap(), full);
        // Absent fields stay None; an empty payload is the empty update.
        let sparse = AutoscalerUpdate {
            high_queue: Some(4.0),
            ..AutoscalerUpdate::default()
        };
        let back = decode_autoscaler_update(&encode_autoscaler_update(&sparse)).unwrap();
        assert_eq!(back, sparse);
        assert!(decode_autoscaler_update(&Json::obj()).unwrap().is_empty());
        // Wrong types are typed errors, not panics or silent Nones.
        for bad in [
            Json::obj().set("enabled", 1u64),
            Json::obj().set("low_queue", "fast"),
            Json::obj().set("cooldown_ticks", -1.0),
            Json::obj().set("cooldown_ticks", 4294967296.0),
        ] {
            assert!(
                matches!(
                    decode_autoscaler_update(&bad),
                    Err(ProtocolError::Malformed(_))
                ),
                "{bad:?} should be malformed"
            );
        }
    }
}
