//! Out-of-process fleet serving.
//!
//! Everything in [`coordinator`](crate::coordinator) is in-process: one
//! binary owns the [`Fleet`](crate::coordinator::Fleet) and calls it
//! through Rust. This module puts that fleet on a socket:
//!
//! * [`protocol`] — the versioned, line-delimited JSON wire format
//!   (16 verbs spanning the data plane, the full controller surface,
//!   the autoscaler, and version negotiation; typed error frames that
//!   round-trip [`SubmitError`](crate::coordinator::SubmitError)).
//!   Protocol **v2** moves image pixels out of the JSON header into a
//!   length-prefixed little-endian f32 block after the line — see the
//!   frame-layout section in [`protocol`]'s docs.
//! * [`server`] — [`NetServer`]: binds TCP or a Unix socket over a live
//!   fleet (`tilekit serve --listen`), bounded accept loop, and a
//!   per-connection reader → worker-pool → writer pipeline, so a slow
//!   `wait` never head-of-line-blocks a `topology` on the same
//!   connection; idle/read timeouts, graceful ticket-draining shutdown.
//! * [`client`] — [`FleetClient`]: the same `submit(...)?.wait()?` and
//!   controller surface, blocking, over the wire (`tilekit fleet
//!   --connect`, `tilekit submit --connect`). Pipelines calls from all
//!   clones over one connection, negotiates v2 (falling back to v1
//!   against old servers), and redials dead connections automatically
//!   with jittered exponential backoff.
//! * [`shard`] — [`FrontTier`]: consistent-hash routing by request
//!   shape across N fleet servers with health-driven failover and
//!   merged stats (`tilekit front --shards`).

pub mod client;
pub mod protocol;
pub mod server;
pub mod shard;

pub use client::{ClientError, FleetClient, NetClientConfig, RemoteTicket, WireMetrics};
pub use protocol::{
    AutoscalerDesc, PayloadEncoding, ProtocolError, RequestFrame, ResponseFrame, TopologyDesc,
    Verb, WireError, WireErrorKind, WireStats, PROTOCOL_V2, PROTOCOL_VERSION,
};
pub use server::{BackendFactory, ListenAddr, NetServer, NetServerConfig};
pub use shard::{shape_hash, FrontTier, FrontTierConfig, Ring, ShardView};
