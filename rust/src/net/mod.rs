//! Out-of-process fleet serving.
//!
//! Everything in [`coordinator`](crate::coordinator) is in-process: one
//! binary owns the [`Fleet`](crate::coordinator::Fleet) and calls it
//! through Rust. This module puts that fleet on a socket:
//!
//! * [`protocol`] — the versioned, line-delimited JSON wire format
//!   (15 verbs spanning the data plane, the full controller surface,
//!   and the autoscaler, typed error frames that round-trip
//!   [`SubmitError`](crate::coordinator::SubmitError)).
//! * [`server`] — [`NetServer`]: binds TCP or a Unix socket over a live
//!   fleet (`tilekit serve --listen`), bounded accept loop,
//!   per-connection reader/writer threads, idle/read timeouts, graceful
//!   ticket-draining shutdown.
//! * [`client`] — [`FleetClient`]: the same `submit(...)?.wait()?` and
//!   controller surface, blocking, over the wire (`tilekit fleet
//!   --connect`, `tilekit submit --connect`).
//! * [`shard`] — [`FrontTier`]: consistent-hash routing by request
//!   shape across N fleet servers with health-driven failover and
//!   merged stats (`tilekit front --shards`).

pub mod client;
pub mod protocol;
pub mod server;
pub mod shard;

pub use client::{ClientError, FleetClient, NetClientConfig, RemoteTicket};
pub use protocol::{
    AutoscalerDesc, ProtocolError, RequestFrame, ResponseFrame, TopologyDesc, Verb, WireError,
    WireErrorKind, WireStats, PROTOCOL_VERSION,
};
pub use server::{BackendFactory, ListenAddr, NetServer, NetServerConfig};
pub use shard::{shape_hash, FrontTier, FrontTierConfig, Ring, ShardView};
