//! The serving side of the wire protocol: `tilekit serve --listen`.
//!
//! [`NetServer::bind`] attaches a listener (TCP or Unix socket) to a
//! live [`Fleet`] and serves the full protocol — the data plane
//! (`submit`/`wait`/`try_wait`/`cancel`) against the fleet and every
//! control-plane verb against its [`FleetController`].
//!
//! Threading model: one accept-loop thread polls a nonblocking listener
//! under a connection cap; each accepted connection gets a **reader**
//! thread (frames bytes, answers `hello`, consumes binary blocks), a
//! small **worker pool** that executes verbs pulled from a bounded
//! queue, and a **writer** thread (serializes responses from a
//! channel). Pipelined clients keep many calls in flight on one
//! connection; because the workers run concurrently, a slow `wait`
//! never head-of-line-blocks a `topology`, and the bounded work queue
//! turns a flooding client into plain TCP backpressure. Responses may
//! complete out of order — frame ids do the matching.
//!
//! Shutdown is graceful: new submits are refused with
//! [`SubmitError::ShuttingDown`], the listener stops accepting, and the
//! server waits (bounded by `drain_timeout`) for every ticket handed to
//! a remote caller to resolve before connections are torn down.

use super::protocol::{
    self, encode_topology, read_frame_line, read_payload, AutoscalerDesc, ProtocolError,
    RequestFrame, ResponseFrame, Verb, WireError, WireErrorKind, WireStats,
    DEFAULT_MAX_LINE_BYTES, PROTOCOL_V2, PROTOCOL_VERSION,
};
use crate::codec::json::Json;
use crate::coordinator::{AutoscalerHandle, Fleet, FleetController, SubmitError, Ticket};
use crate::device::DeviceDescriptor;
use crate::runtime::ResizeBackend;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::fmt;
use std::io::{BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Where a server listens or a client connects: `host:port` TCP, or
/// `unix:/path/to.sock`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ListenAddr {
    Tcp(String),
    Unix(PathBuf),
}

impl ListenAddr {
    /// Parse and validate an address string. TCP addresses must be
    /// `host:port` with a numeric port; Unix sockets use a `unix:`
    /// prefix followed by a non-empty path.
    pub fn parse(s: &str) -> Result<ListenAddr> {
        if let Some(path) = s.strip_prefix("unix:") {
            if path.is_empty() {
                return Err(anyhow!("unix socket address needs a path after 'unix:'"));
            }
            return Ok(ListenAddr::Unix(PathBuf::from(path)));
        }
        let (host, port) = s
            .rsplit_once(':')
            .ok_or_else(|| anyhow!("TCP listen address must be host:port, got '{s}'"))?;
        if host.is_empty() {
            return Err(anyhow!("TCP listen address '{s}' has an empty host"));
        }
        port.parse::<u16>()
            .map_err(|_| anyhow!("'{port}' is not a valid TCP port (0-65535)"))?;
        Ok(ListenAddr::Tcp(s.to_string()))
    }
}

impl fmt::Display for ListenAddr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ListenAddr::Tcp(a) => f.write_str(a),
            ListenAddr::Unix(p) => write!(f, "unix:{}", p.display()),
        }
    }
}

/// Builds a backend for a device joining the fleet via a remote
/// `add_member` — the server cannot receive a live backend over the
/// wire, so the operator supplies the recipe at bind time (e.g. "mock
/// engine over this manifest").
pub type BackendFactory = Arc<dyn Fn(&DeviceDescriptor) -> Arc<dyn ResizeBackend> + Send + Sync>;

/// Tunables for a [`NetServer`]; defaults come from
/// [`NetConfig`](crate::config::NetConfig).
#[derive(Debug, Clone)]
pub struct NetServerConfig {
    /// Concurrent connection cap; excess connections get a typed error
    /// frame and are closed.
    pub max_conns: usize,
    /// Socket read timeout — the reader's poll tick for shutdown/idle
    /// checks.
    pub read_timeout: Duration,
    /// Close a connection with no complete frame for this long.
    pub idle_timeout: Duration,
    /// Per-line byte cap (frame size bound); binary payload blocks are
    /// held to the same budget.
    pub max_line_bytes: usize,
    /// How long graceful shutdown waits for outstanding remote tickets.
    pub drain_timeout: Duration,
    /// Bound on queued-but-unexecuted frames per connection. A pipelined
    /// client past this depth blocks in the reader — TCP backpressure,
    /// not unbounded server memory.
    pub max_inflight_per_conn: usize,
}

impl Default for NetServerConfig {
    fn default() -> NetServerConfig {
        NetServerConfig {
            max_conns: 64,
            read_timeout: Duration::from_millis(250),
            idle_timeout: Duration::from_secs(30),
            max_line_bytes: DEFAULT_MAX_LINE_BYTES,
            drain_timeout: Duration::from_secs(10),
            max_inflight_per_conn: 32,
        }
    }
}

/// Verb-execution threads per connection. Small on purpose: enough that
/// a blocking `wait` (bounded at 5 s) cannot starve control verbs, yet
/// a saturated server stays at a sane thread count.
const CONN_WORKERS: usize = 4;

enum Listener {
    Tcp(TcpListener),
    Unix(UnixListener, PathBuf),
}

enum Stream {
    Tcp(TcpStream),
    Unix(UnixStream),
}

impl Stream {
    fn split(&self, read_timeout: Duration) -> std::io::Result<(Stream, Stream)> {
        match self {
            Stream::Tcp(s) => {
                s.set_read_timeout(Some(read_timeout))?;
                Ok((Stream::Tcp(s.try_clone()?), Stream::Tcp(s.try_clone()?)))
            }
            Stream::Unix(s) => {
                s.set_read_timeout(Some(read_timeout))?;
                Ok((Stream::Unix(s.try_clone()?), Stream::Unix(s.try_clone()?)))
            }
        }
    }

    fn shutdown_both(&self) {
        let _ = match self {
            Stream::Tcp(s) => s.shutdown(std::net::Shutdown::Both),
            Stream::Unix(s) => s.shutdown(std::net::Shutdown::Both),
        };
    }
}

impl std::io::Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            Stream::Unix(s) => s.write(buf),
        }
    }
    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            Stream::Unix(s) => s.flush(),
        }
    }
}

struct ServerShared {
    fleet: Arc<Fleet>,
    controller: FleetController,
    backends: BackendFactory,
    /// Live autoscaler knobs, when `serve --autoscale` started one —
    /// answers the `autoscaler`/`set_autoscaler` verbs.
    autoscaler: Option<AutoscalerHandle>,
    cfg: NetServerConfig,
    /// Set by [`NetServer::shutdown`]: refuse submits, stop accepting.
    closed: AtomicBool,
    /// Tickets handed to remote callers that have not resolved yet.
    open_tickets: AtomicU64,
    conns: AtomicUsize,
}

/// A fleet bound to a socket, serving the wire protocol until
/// [`shutdown`](NetServer::shutdown).
pub struct NetServer {
    shared: Arc<ServerShared>,
    accept: Option<thread::JoinHandle<()>>,
    local: ListenAddr,
    /// Unix socket path to unlink on shutdown.
    sock_path: Option<PathBuf>,
}

impl NetServer {
    /// Bind `addr` and start serving `fleet`. For TCP, port `0` picks an
    /// ephemeral port — read the resolved address back from
    /// [`local_addr`](NetServer::local_addr). A stale Unix socket file
    /// from a dead server is replaced.
    pub fn bind(
        addr: &ListenAddr,
        fleet: Arc<Fleet>,
        backends: BackendFactory,
        cfg: NetServerConfig,
    ) -> Result<NetServer> {
        NetServer::bind_with(addr, fleet, backends, None, cfg)
    }

    /// [`bind`](NetServer::bind), plus an optional [`AutoscalerHandle`]
    /// so remote callers can inspect and reconfigure the capacity loop
    /// (`tilekit fleet autoscaler ... --connect`). Without one, the
    /// `autoscaler`/`set_autoscaler` verbs answer not-found.
    pub fn bind_with(
        addr: &ListenAddr,
        fleet: Arc<Fleet>,
        backends: BackendFactory,
        autoscaler: Option<AutoscalerHandle>,
        cfg: NetServerConfig,
    ) -> Result<NetServer> {
        let (listener, local, sock_path) = match addr {
            ListenAddr::Tcp(a) => {
                let l = TcpListener::bind(a.as_str())
                    .with_context(|| format!("binding tcp listener on {a}"))?;
                let resolved = l
                    .local_addr()
                    .map(|sa| sa.to_string())
                    .unwrap_or_else(|_| a.clone());
                (Listener::Tcp(l), ListenAddr::Tcp(resolved), None)
            }
            ListenAddr::Unix(p) => {
                // Connect-probe a pre-existing socket: refuse to replace
                // a live server, but clean up after a dead one.
                if p.exists() {
                    if UnixStream::connect(p).is_ok() {
                        return Err(anyhow!(
                            "unix socket {} already has a listening server",
                            p.display()
                        ));
                    }
                    std::fs::remove_file(p)
                        .with_context(|| format!("removing stale socket {}", p.display()))?;
                }
                let l = UnixListener::bind(p)
                    .with_context(|| format!("binding unix listener on {}", p.display()))?;
                (
                    Listener::Unix(l, p.clone()),
                    ListenAddr::Unix(p.clone()),
                    Some(p.clone()),
                )
            }
        };
        match &listener {
            Listener::Tcp(l) => l.set_nonblocking(true)?,
            Listener::Unix(l, _) => l.set_nonblocking(true)?,
        }
        let shared = Arc::new(ServerShared {
            controller: fleet.controller(),
            fleet,
            backends,
            autoscaler,
            cfg,
            closed: AtomicBool::new(false),
            open_tickets: AtomicU64::new(0),
            conns: AtomicUsize::new(0),
        });
        let accept = {
            let shared = Arc::clone(&shared);
            thread::Builder::new()
                .name("net-accept".into())
                .spawn(move || accept_loop(listener, shared))
                .context("spawning accept loop")?
        };
        Ok(NetServer {
            shared,
            accept: Some(accept),
            local: local.clone(),
            sock_path,
        })
    }

    /// The bound address — for TCP this has the real port even when the
    /// caller asked for `:0`.
    pub fn local_addr(&self) -> &ListenAddr {
        &self.local
    }

    /// Tickets handed to remote callers that have not resolved yet.
    pub fn open_tickets(&self) -> u64 {
        self.shared.open_tickets.load(Ordering::SeqCst)
    }

    /// Live connections.
    pub fn connections(&self) -> usize {
        self.shared.conns.load(Ordering::SeqCst)
    }

    /// Graceful shutdown: refuse new submits, stop accepting, wait
    /// (bounded by `drain_timeout`) for outstanding remote tickets to
    /// resolve, then tear down connections. The fleet itself is NOT shut
    /// down — the caller still owns its `Arc<Fleet>`.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        if self.shared.closed.swap(true, Ordering::SeqCst) {
            return;
        }
        let drain_deadline = Instant::now() + self.shared.cfg.drain_timeout;
        while self.shared.open_tickets.load(Ordering::SeqCst) > 0
            && Instant::now() < drain_deadline
        {
            thread::sleep(Duration::from_millis(5));
        }
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // Readers notice `closed` at their next read-timeout tick.
        let conn_deadline =
            Instant::now() + self.shared.cfg.read_timeout * 4 + Duration::from_secs(1);
        while self.shared.conns.load(Ordering::SeqCst) > 0 && Instant::now() < conn_deadline {
            thread::sleep(Duration::from_millis(5));
        }
        if let Some(p) = self.sock_path.take() {
            let _ = std::fs::remove_file(p);
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn accept_loop(listener: Listener, shared: Arc<ServerShared>) {
    loop {
        if shared.closed.load(Ordering::SeqCst) {
            return;
        }
        let accepted = match &listener {
            Listener::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s)),
            Listener::Unix(l, _) => l.accept().map(|(s, _)| Stream::Unix(s)),
        };
        match accepted {
            Ok(stream) => {
                if shared.conns.load(Ordering::SeqCst) >= shared.cfg.max_conns {
                    refuse_connection(stream, shared.cfg.max_conns);
                    continue;
                }
                shared.conns.fetch_add(1, Ordering::SeqCst);
                let guard = ConnGuard(Arc::clone(&shared));
                // If the spawn fails, the closure (and the guard inside
                // it) is dropped right here, settling the count.
                let _ = thread::Builder::new()
                    .name("net-conn".into())
                    .spawn(move || {
                        serve_connection(stream, &guard.0);
                    });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(5));
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => {
                // Listener died under us; stop accepting. Existing
                // connections keep running until shutdown.
                return;
            }
        }
    }
}

/// Decrements the live-connection count when dropped — including when
/// the connection thread unwinds from a panic — so a crashed connection
/// can never wedge the accept loop's `max_conns` budget or stall
/// shutdown's connection drain.
struct ConnGuard(Arc<ServerShared>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.conns.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Per-connection state shared by the reader and its verb workers: the
/// negotiated session version and the connection's outstanding tickets.
/// On drop — clean exit or panic unwinding — tickets the client never
/// collected are subtracted from the server-wide open-ticket count, so
/// graceful shutdown is not held hostage by a vanished (or crashed)
/// connection.
struct ConnSession {
    shared: Arc<ServerShared>,
    /// The negotiated protocol version; starts at the baseline and is
    /// raised by a `hello` exchange. Responses are stamped with it.
    version: AtomicU64,
    tickets: Mutex<HashMap<u64, Ticket>>,
}

impl ConnSession {
    fn version(&self) -> u64 {
        self.version.load(Ordering::SeqCst)
    }
}

impl Drop for ConnSession {
    fn drop(&mut self) {
        // analyze::allow(no-as-narrowing-in-decode): usize -> u64
        // widening of a local table length; cannot truncate.
        let abandoned = self.tickets.get_mut().map(|t| t.len()).unwrap_or(0) as u64;
        if abandoned > 0 {
            self.shared
                .open_tickets
                .fetch_sub(abandoned, Ordering::SeqCst);
        }
    }
}

/// Over-cap connection: best-effort typed error frame, then close.
fn refuse_connection(mut stream: Stream, cap: usize) {
    let frame = ResponseFrame::err(
        0,
        WireError::new(
            WireErrorKind::Saturated,
            format!("server connection limit ({cap}) reached"),
        ),
    );
    let _ = stream.write_all(frame.to_line().as_bytes());
    let _ = stream.flush();
    stream.shutdown_both();
}

/// Per-connection reader: frame the byte stream, answer `hello`
/// inline, and feed everything else to the connection's worker pool.
fn serve_connection(stream: Stream, shared: &Arc<ServerShared>) {
    let (read_half, write_half) = match stream.split(shared.cfg.read_timeout) {
        Ok(halves) => halves,
        Err(_) => {
            stream.shutdown_both();
            return;
        }
    };
    let (tx, rx) = mpsc::channel::<Vec<u8>>();
    let writer = thread::Builder::new()
        .name("net-write".into())
        .spawn(move || writer_loop(write_half, rx));
    let writer = match writer {
        Ok(w) => w,
        Err(_) => {
            stream.shutdown_both();
            return;
        }
    };

    let session = Arc::new(ConnSession {
        shared: Arc::clone(shared),
        version: AtomicU64::new(PROTOCOL_VERSION),
        tickets: Mutex::new(HashMap::new()),
    });
    // The bounded queue is the per-connection inflight cap: when a
    // pipelining client outruns the workers, the reader blocks here and
    // the kernel's socket buffers push back on the client.
    let (work_tx, work_rx) =
        mpsc::sync_channel::<(RequestFrame, Option<Vec<u8>>)>(shared.cfg.max_inflight_per_conn);
    let work_rx = Arc::new(Mutex::new(work_rx));
    let workers: Vec<_> = (0..CONN_WORKERS)
        .filter_map(|i| {
            let rx = Arc::clone(&work_rx);
            let tx = tx.clone();
            let session = Arc::clone(&session);
            thread::Builder::new()
                .name(format!("net-verb-{i}"))
                .spawn(move || worker_loop(&session, &rx, &tx))
                .ok()
        })
        .collect();
    if workers.is_empty() {
        drop(work_tx);
        drop(tx);
        let _ = writer.join();
        stream.shutdown_both();
        return;
    }

    let mut reader = BufReader::new(read_half);
    let mut last_activity = Instant::now();
    // Reports a framing-level problem on the id-0 out-of-band channel.
    let report = |e: &dyn fmt::Display| {
        let f = ResponseFrame::err(0, WireError::new(WireErrorKind::Protocol, e.to_string()));
        tx.send(f.to_wire(session.version(), None)).is_ok()
    };
    loop {
        if shared.closed.load(Ordering::SeqCst)
            && session.tickets.lock().map(|t| t.is_empty()).unwrap_or(true)
        {
            break;
        }
        let line = match read_frame_line(&mut reader, shared.cfg.max_line_bytes) {
            Ok(Some(line)) => line,
            Ok(None) => break,
            Err(ProtocolError::Timeout) => {
                if last_activity.elapsed() > shared.cfg.idle_timeout {
                    break;
                }
                continue;
            }
            Err(e @ (ProtocolError::Oversized { .. } | ProtocolError::Truncated)) => {
                report(&e);
                break;
            }
            Err(_) => break,
        };
        last_activity = Instant::now();
        let header = match Json::parse(line.trim_end_matches(['\r', '\n'])) {
            Ok(j) => j,
            Err(e) => {
                // Line framing survives a non-JSON line; report it and
                // keep the connection.
                report(&ProtocolError::Malformed(e.to_string()));
                continue;
            }
        };
        // Consume the binary block before judging the header, so a
        // well-formed-JSON-but-bad frame cannot desynchronize framing.
        let blob = match protocol::frame_extra_bytes(&header) {
            Ok(0) => None,
            Ok(n) => match read_payload(&mut reader, n, shared.cfg.max_line_bytes) {
                Ok(b) => Some(b),
                Err(e) => {
                    report(&e);
                    break;
                }
            },
            Err(e) => {
                // `payload_bytes` itself unreadable: the block length is
                // unknown, so framing is lost — close.
                report(&e);
                break;
            }
        };
        let frame = match RequestFrame::from_json(&header) {
            Ok(f) => f,
            Err(e @ ProtocolError::Version { .. }) => {
                report(&e);
                break;
            }
            Err(e) => {
                report(&e);
                continue;
            }
        };
        if frame.verb == Verb::Hello {
            // Answered inline (not pooled) so the version flips before
            // any later frame's response is encoded.
            let v = protocol::negotiate(protocol::decode_hello_max(&frame.payload), PROTOCOL_V2);
            let resp = ok(frame.id, Json::obj().set("version", v));
            // The reply itself is pre-upgrade: stamp it baseline.
            if tx.send(resp.to_wire(PROTOCOL_VERSION, None)).is_err() {
                break;
            }
            session.version.store(v, Ordering::SeqCst);
            continue;
        }
        if work_tx.send((frame, blob)).is_err() {
            break;
        }
    }
    drop(work_tx); // workers drain the queue, then exit
    for w in workers {
        let _ = w.join();
    }
    // Settles any tickets the client never collected via its Drop —
    // the workers' session clones are gone once they are joined.
    drop(session);
    drop(tx); // writer drains then exits
    let _ = writer.join();
    stream.shutdown_both();
}

/// One verb-execution worker: pull a frame, run it, hand the encoded
/// response to the writer. Exits when the reader drops the work queue
/// or the writer goes away.
fn worker_loop(
    session: &Arc<ConnSession>,
    work_rx: &Arc<Mutex<mpsc::Receiver<(RequestFrame, Option<Vec<u8>>)>>>,
    tx: &mpsc::Sender<Vec<u8>>,
) {
    loop {
        let job = match work_rx.lock() {
            Ok(rx) => rx.recv(),
            Err(_) => return,
        };
        let Ok((frame, blob)) = job else { return };
        let (resp, resp_blob) = dispatch(session, frame, blob.as_deref());
        if tx
            .send(resp.to_wire(session.version(), resp_blob.as_deref()))
            .is_err()
        {
            return;
        }
    }
}

fn writer_loop(mut w: Stream, rx: mpsc::Receiver<Vec<u8>>) {
    while let Ok(bytes) = rx.recv() {
        if w.write_all(&bytes).is_err() || w.flush().is_err() {
            return;
        }
    }
}

fn ok(id: u64, body: Json) -> ResponseFrame {
    ResponseFrame::ok(id, body)
}

fn err(id: u64, kind: WireErrorKind, msg: impl Into<String>) -> ResponseFrame {
    ResponseFrame::err(id, WireError::new(kind, msg))
}

/// Encode a resolved image at the session's negotiated version: inline
/// JSON pixels at baseline, a binary block in a v2 session.
fn image_body(session: &ConnSession, img: &crate::image::Image<f32>) -> (Json, Option<Vec<u8>>) {
    if session.version() >= PROTOCOL_V2 {
        let (header, blob) = protocol::encode_image_blob(img);
        (header, Some(blob))
    } else {
        (protocol::encode_image(img), None)
    }
}

/// Execute one verb against the fleet/controller. Returns the response
/// frame plus the binary block backing it, when the session version
/// ships pixels out of band.
fn dispatch(
    session: &ConnSession,
    frame: RequestFrame,
    blob: Option<&[u8]>,
) -> (ResponseFrame, Option<Vec<u8>>) {
    let shared = &session.shared;
    let id = frame.id;
    let p = &frame.payload;
    // Poison recovery instead of expect: a worker that panicked while
    // holding the table must not turn every later frame on this
    // connection into a second panic (the table holds plain data).
    let lock_tickets = || {
        session
            .tickets
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
    };
    let plain = |resp: ResponseFrame| (resp, None);
    match frame.verb {
        // The reader answers hello inline before the pool; a mid-stream
        // repeat landing here is a protocol misuse, not a crash.
        Verb::Hello => plain(err(
            id,
            WireErrorKind::Protocol,
            "hello must be the first frame on a connection",
        )),
        Verb::Submit => {
            if shared.closed.load(Ordering::SeqCst) {
                return plain(ResponseFrame::err(
                    id,
                    WireError::from_submit(&SubmitError::ShuttingDown),
                ));
            }
            let req = match protocol::decode_submit_with(p, blob) {
                Ok(r) => r,
                Err(e) => return plain(err(id, WireErrorKind::Protocol, e.to_string())),
            };
            match shared.fleet.submit(req) {
                Ok(ticket) => {
                    shared.open_tickets.fetch_add(1, Ordering::SeqCst);
                    let body = Json::obj().set("ticket", ticket.id);
                    let body = match ticket.device_id() {
                        Some(d) => body.set("device", d),
                        None => body,
                    };
                    lock_tickets().insert(ticket.id, ticket);
                    plain(ok(id, body))
                }
                Err(e) => plain(ResponseFrame::err(id, WireError::from_submit(&e))),
            }
        }
        Verb::Wait => {
            let Some(tid) = p.get("ticket").and_then(Json::as_u64) else {
                return plain(err(id, WireErrorKind::Protocol, "wait missing 'ticket'"));
            };
            // Per-call budget, capped so one call never outlives the
            // idle timeout; the client loops until done. NaN (which
            // clamp passes through) falls back to the default.
            let budget_ms = p
                .get("timeout_ms")
                .and_then(Json::as_f64)
                .filter(|ms| ms.is_finite())
                .unwrap_or(1000.0)
                .clamp(0.0, 5000.0);
            // Removing the ticket claims it for this call — a second
            // pipelined wait on the same id sees not-found rather than
            // two workers blocking on one resolution.
            let Some(ticket) = lock_tickets().remove(&tid) else {
                return plain(err(id, WireErrorKind::NotFound, format!("no ticket {tid}")));
            };
            let deadline = Instant::now() + protocol::saturating_duration_from_ms(budget_ms);
            loop {
                let left = deadline.saturating_duration_since(Instant::now());
                let step = left.min(Duration::from_millis(100));
                match ticket.wait_timeout(step) {
                    Ok(Some(img)) => {
                        shared.open_tickets.fetch_sub(1, Ordering::SeqCst);
                        let (image, blob) = image_body(session, &img);
                        let body = Json::obj().set("done", true).set("image", image);
                        return (ok(id, body), blob);
                    }
                    Ok(None) => {
                        if Instant::now() >= deadline {
                            lock_tickets().insert(tid, ticket);
                            return plain(ok(id, Json::obj().set("done", false)));
                        }
                    }
                    Err(e) => {
                        shared.open_tickets.fetch_sub(1, Ordering::SeqCst);
                        return plain(err(id, WireErrorKind::Failed, format!("{e:#}")));
                    }
                }
            }
        }
        Verb::TryWait => {
            let Some(tid) = p.get("ticket").and_then(Json::as_u64) else {
                return plain(err(id, WireErrorKind::Protocol, "try_wait missing 'ticket'"));
            };
            let mut tickets = lock_tickets();
            let Some(ticket) = tickets.get(&tid) else {
                return plain(err(id, WireErrorKind::NotFound, format!("no ticket {tid}")));
            };
            match ticket.try_wait() {
                Ok(Some(img)) => {
                    let (image, blob) = image_body(session, &img);
                    let body = Json::obj().set("done", true).set("image", image);
                    tickets.remove(&tid);
                    shared.open_tickets.fetch_sub(1, Ordering::SeqCst);
                    (ok(id, body), blob)
                }
                Ok(None) => plain(ok(id, Json::obj().set("done", false))),
                Err(e) => {
                    tickets.remove(&tid);
                    shared.open_tickets.fetch_sub(1, Ordering::SeqCst);
                    plain(err(id, WireErrorKind::Failed, format!("{e:#}")))
                }
            }
        }
        Verb::Cancel => {
            let Some(tid) = p.get("ticket").and_then(Json::as_u64) else {
                return plain(err(id, WireErrorKind::Protocol, "cancel missing 'ticket'"));
            };
            let tickets = lock_tickets();
            let Some(ticket) = tickets.get(&tid) else {
                return plain(err(id, WireErrorKind::NotFound, format!("no ticket {tid}")));
            };
            ticket.cancel();
            // The ticket stays registered: a later wait/try_wait
            // observes the cancelled resolution and settles the count.
            plain(ok(id, Json::obj().set("cancelled", true)))
        }
        Verb::Topology => plain(ok(id, encode_topology(&shared.controller.topology()))),
        Verb::AddMember => {
            let Some(dev_id) = p.get("device").and_then(Json::as_str) else {
                return plain(err(id, WireErrorKind::Protocol, "add_member missing 'device'"));
            };
            let Some(desc) = crate::device::find_device(dev_id) else {
                return plain(err(
                    id,
                    WireErrorKind::NotFound,
                    format!("no device '{dev_id}' in the registry"),
                ));
            };
            let policy = match p.get("policy") {
                Some(pp) => match protocol::decode_policy(pp) {
                    Ok(pol) => pol,
                    Err(e) => return plain(err(id, WireErrorKind::Protocol, e.to_string())),
                },
                None => crate::coordinator::TilePolicy::PortableFallback,
            };
            let backend = (shared.backends)(&desc);
            plain(match shared.controller.add_member(desc, backend, policy) {
                Ok(member) => ok(
                    id,
                    Json::obj()
                        .set("member", member)
                        .set("epoch", shared.controller.epoch()),
                ),
                Err(e) => err(id, WireErrorKind::Internal, format!("{e:#}")),
            })
        }
        Verb::RemoveMember => {
            let Some(dev_id) = p.get("device").and_then(Json::as_str) else {
                return plain(err(
                    id,
                    WireErrorKind::Protocol,
                    "remove_member missing 'device'",
                ));
            };
            let mode = match p.get("mode").and_then(Json::as_str) {
                None => crate::coordinator::DrainMode::Graceful,
                Some(m) => match protocol::parse_drain_mode(m) {
                    Ok(m) => m,
                    Err(e) => return plain(err(id, WireErrorKind::Protocol, e.to_string())),
                },
            };
            plain(match shared.controller.remove_member(dev_id, mode) {
                Ok(()) => ok(id, Json::obj().set("epoch", shared.controller.epoch())),
                Err(e) => err(id, WireErrorKind::NotFound, format!("{e:#}")),
            })
        }
        Verb::Drain => {
            let Some(dev_id) = p.get("device").and_then(Json::as_str) else {
                return plain(err(id, WireErrorKind::Protocol, "drain missing 'device'"));
            };
            plain(match shared.controller.drain(dev_id) {
                Ok(()) => ok(id, Json::obj().set("epoch", shared.controller.epoch())),
                Err(e) => err(id, WireErrorKind::NotFound, format!("{e:#}")),
            })
        }
        Verb::Retune => {
            let Some(dev_id) = p.get("device").and_then(Json::as_str) else {
                return plain(err(id, WireErrorKind::Protocol, "retune missing 'device'"));
            };
            let Some(oj) = p.get("outcome") else {
                return plain(err(id, WireErrorKind::Protocol, "retune missing 'outcome'"));
            };
            let outcome = match crate::autotuner::TuningOutcome::from_json(oj) {
                Ok(o) => o,
                Err(e) => return plain(err(id, WireErrorKind::Protocol, format!("{e:#}"))),
            };
            plain(match shared.controller.retune(dev_id, &outcome) {
                Ok(tile) => ok(
                    id,
                    Json::obj().set(
                        "tile",
                        match tile {
                            Some(t) => Json::Str(t.label()),
                            None => Json::Null,
                        },
                    ),
                ),
                Err(e) => err(id, WireErrorKind::NotFound, format!("{e:#}")),
            })
        }
        Verb::SetScheduler => {
            let Some(name) = p.get("name").and_then(Json::as_str) else {
                return plain(err(id, WireErrorKind::Protocol, "set_scheduler missing 'name'"));
            };
            plain(match shared.controller.set_scheduler_by_name(name) {
                Ok(()) => ok(id, Json::obj().set("ok", true)),
                Err(e) => err(id, WireErrorKind::Protocol, format!("{e:#}")),
            })
        }
        Verb::SetAdmission => {
            let Some(name) = p.get("name").and_then(Json::as_str) else {
                return plain(err(id, WireErrorKind::Protocol, "set_admission missing 'name'"));
            };
            let timeout_ms = p.get("timeout_ms").and_then(Json::as_f64).unwrap_or(50.0);
            let timeout = match protocol::duration_from_ms(timeout_ms, "timeout_ms") {
                Ok(t) => t,
                Err(e) => return plain(err(id, WireErrorKind::Protocol, e.to_string())),
            };
            plain(match shared.controller.set_admission_by_name(name, timeout) {
                Ok(()) => ok(id, Json::obj().set("ok", true)),
                Err(e) => err(id, WireErrorKind::Protocol, format!("{e:#}")),
            })
        }
        Verb::SetStealConfig => {
            let Some(enabled) = p.get("enabled").and_then(Json::as_bool) else {
                return plain(err(
                    id,
                    WireErrorKind::Protocol,
                    "set_steal_config missing 'enabled'",
                ));
            };
            let Some(threshold) = p.get("threshold").and_then(Json::as_u64) else {
                return plain(err(
                    id,
                    WireErrorKind::Protocol,
                    "set_steal_config missing 'threshold'",
                ));
            };
            // A threshold past usize::MAX (32-bit targets) saturates: it
            // means "never steal", which is what the peer asked for.
            let threshold = usize::try_from(threshold).unwrap_or(usize::MAX);
            plain(
                match shared.controller.set_steal_config(enabled, threshold) {
                    Ok(()) => ok(id, Json::obj().set("ok", true)),
                    Err(e) => err(id, WireErrorKind::Internal, format!("{e:#}")),
                },
            )
        }
        Verb::Stats => plain(ok(id, WireStats::of(&shared.fleet.stats()).to_json())),
        Verb::Autoscaler => plain(match &shared.autoscaler {
            Some(h) => ok(id, AutoscalerDesc::of(&h.view()).to_json()),
            None => err(id, WireErrorKind::NotFound, "no autoscaler running"),
        }),
        Verb::SetAutoscaler => {
            let Some(h) = &shared.autoscaler else {
                return plain(err(id, WireErrorKind::NotFound, "no autoscaler running"));
            };
            let update = match protocol::decode_autoscaler_update(p) {
                Ok(u) => u,
                Err(e) => return plain(err(id, WireErrorKind::Protocol, e.to_string())),
            };
            plain(match h.apply(&update) {
                Ok(()) => ok(id, AutoscalerDesc::of(&h.view()).to_json()),
                Err(e) => err(id, WireErrorKind::Protocol, format!("{e:#}")),
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn listen_addr_parses_and_displays() {
        assert_eq!(
            ListenAddr::parse("127.0.0.1:7441").unwrap(),
            ListenAddr::Tcp("127.0.0.1:7441".into())
        );
        assert_eq!(
            ListenAddr::parse("unix:/tmp/tilekit.sock").unwrap(),
            ListenAddr::Unix(PathBuf::from("/tmp/tilekit.sock"))
        );
        assert_eq!(
            ListenAddr::parse("unix:/tmp/x.sock").unwrap().to_string(),
            "unix:/tmp/x.sock"
        );
        assert_eq!(
            ListenAddr::parse("[::1]:0").unwrap().to_string(),
            "[::1]:0"
        );
        for bad in ["", "noport", ":7441", "host:", "host:notaport", "host:99999", "unix:"] {
            assert!(ListenAddr::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }
}
