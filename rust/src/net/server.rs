//! The serving side of the wire protocol: `tilekit serve --listen`.
//!
//! [`NetServer::bind`] attaches a listener (TCP or Unix socket) to a
//! live [`Fleet`] and serves the full protocol — the data plane
//! (`submit`/`wait`/`try_wait`/`cancel`) against the fleet and every
//! control-plane verb against its [`FleetController`].
//!
//! Threading model: one accept-loop thread polls a nonblocking listener
//! under a connection cap; each accepted connection gets a **reader**
//! thread (parses frames, executes verbs) and a **writer** thread
//! (serializes responses from a channel), so a slow client write never
//! stalls verb execution. Because the [`FleetClient`](super::FleetClient)
//! keeps one outstanding call per connection, `wait` is served inline
//! with a bounded per-call timeout — the client re-polls, and responses
//! stay in order.
//!
//! Shutdown is graceful: new submits are refused with
//! [`SubmitError::ShuttingDown`], the listener stops accepting, and the
//! server waits (bounded by `drain_timeout`) for every ticket handed to
//! a remote caller to resolve before connections are torn down.

use super::protocol::{
    self, encode_topology, read_frame_line, AutoscalerDesc, ProtocolError, RequestFrame,
    ResponseFrame, Verb, WireError, WireErrorKind, WireStats, DEFAULT_MAX_LINE_BYTES,
};
use crate::codec::json::Json;
use crate::coordinator::{AutoscalerHandle, Fleet, FleetController, SubmitError, Ticket};
use crate::device::DeviceDescriptor;
use crate::runtime::ResizeBackend;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::fmt;
use std::io::{BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Where a server listens or a client connects: `host:port` TCP, or
/// `unix:/path/to.sock`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ListenAddr {
    Tcp(String),
    Unix(PathBuf),
}

impl ListenAddr {
    /// Parse and validate an address string. TCP addresses must be
    /// `host:port` with a numeric port; Unix sockets use a `unix:`
    /// prefix followed by a non-empty path.
    pub fn parse(s: &str) -> Result<ListenAddr> {
        if let Some(path) = s.strip_prefix("unix:") {
            if path.is_empty() {
                return Err(anyhow!("unix socket address needs a path after 'unix:'"));
            }
            return Ok(ListenAddr::Unix(PathBuf::from(path)));
        }
        let (host, port) = s
            .rsplit_once(':')
            .ok_or_else(|| anyhow!("TCP listen address must be host:port, got '{s}'"))?;
        if host.is_empty() {
            return Err(anyhow!("TCP listen address '{s}' has an empty host"));
        }
        port.parse::<u16>()
            .map_err(|_| anyhow!("'{port}' is not a valid TCP port (0-65535)"))?;
        Ok(ListenAddr::Tcp(s.to_string()))
    }
}

impl fmt::Display for ListenAddr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ListenAddr::Tcp(a) => f.write_str(a),
            ListenAddr::Unix(p) => write!(f, "unix:{}", p.display()),
        }
    }
}

/// Builds a backend for a device joining the fleet via a remote
/// `add_member` — the server cannot receive a live backend over the
/// wire, so the operator supplies the recipe at bind time (e.g. "mock
/// engine over this manifest").
pub type BackendFactory = Arc<dyn Fn(&DeviceDescriptor) -> Arc<dyn ResizeBackend> + Send + Sync>;

/// Tunables for a [`NetServer`]; defaults come from
/// [`NetConfig`](crate::config::NetConfig).
#[derive(Debug, Clone)]
pub struct NetServerConfig {
    /// Concurrent connection cap; excess connections get a typed error
    /// frame and are closed.
    pub max_conns: usize,
    /// Socket read timeout — the reader's poll tick for shutdown/idle
    /// checks.
    pub read_timeout: Duration,
    /// Close a connection with no complete frame for this long.
    pub idle_timeout: Duration,
    /// Per-line byte cap (frame size bound).
    pub max_line_bytes: usize,
    /// How long graceful shutdown waits for outstanding remote tickets.
    pub drain_timeout: Duration,
}

impl Default for NetServerConfig {
    fn default() -> NetServerConfig {
        NetServerConfig {
            max_conns: 64,
            read_timeout: Duration::from_millis(250),
            idle_timeout: Duration::from_secs(30),
            max_line_bytes: DEFAULT_MAX_LINE_BYTES,
            drain_timeout: Duration::from_secs(10),
        }
    }
}

enum Listener {
    Tcp(TcpListener),
    Unix(UnixListener, PathBuf),
}

enum Stream {
    Tcp(TcpStream),
    Unix(UnixStream),
}

impl Stream {
    fn split(&self, read_timeout: Duration) -> std::io::Result<(Stream, Stream)> {
        match self {
            Stream::Tcp(s) => {
                s.set_read_timeout(Some(read_timeout))?;
                Ok((Stream::Tcp(s.try_clone()?), Stream::Tcp(s.try_clone()?)))
            }
            Stream::Unix(s) => {
                s.set_read_timeout(Some(read_timeout))?;
                Ok((Stream::Unix(s.try_clone()?), Stream::Unix(s.try_clone()?)))
            }
        }
    }

    fn shutdown_both(&self) {
        let _ = match self {
            Stream::Tcp(s) => s.shutdown(std::net::Shutdown::Both),
            Stream::Unix(s) => s.shutdown(std::net::Shutdown::Both),
        };
    }
}

impl std::io::Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            Stream::Unix(s) => s.write(buf),
        }
    }
    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            Stream::Unix(s) => s.flush(),
        }
    }
}

struct ServerShared {
    fleet: Arc<Fleet>,
    controller: FleetController,
    backends: BackendFactory,
    /// Live autoscaler knobs, when `serve --autoscale` started one —
    /// answers the `autoscaler`/`set_autoscaler` verbs.
    autoscaler: Option<AutoscalerHandle>,
    cfg: NetServerConfig,
    /// Set by [`NetServer::shutdown`]: refuse submits, stop accepting.
    closed: AtomicBool,
    /// Tickets handed to remote callers that have not resolved yet.
    open_tickets: AtomicU64,
    conns: AtomicUsize,
}

/// A fleet bound to a socket, serving the wire protocol until
/// [`shutdown`](NetServer::shutdown).
pub struct NetServer {
    shared: Arc<ServerShared>,
    accept: Option<thread::JoinHandle<()>>,
    local: ListenAddr,
    /// Unix socket path to unlink on shutdown.
    sock_path: Option<PathBuf>,
}

impl NetServer {
    /// Bind `addr` and start serving `fleet`. For TCP, port `0` picks an
    /// ephemeral port — read the resolved address back from
    /// [`local_addr`](NetServer::local_addr). A stale Unix socket file
    /// from a dead server is replaced.
    pub fn bind(
        addr: &ListenAddr,
        fleet: Arc<Fleet>,
        backends: BackendFactory,
        cfg: NetServerConfig,
    ) -> Result<NetServer> {
        NetServer::bind_with(addr, fleet, backends, None, cfg)
    }

    /// [`bind`](NetServer::bind), plus an optional [`AutoscalerHandle`]
    /// so remote callers can inspect and reconfigure the capacity loop
    /// (`tilekit fleet autoscaler ... --connect`). Without one, the
    /// `autoscaler`/`set_autoscaler` verbs answer not-found.
    pub fn bind_with(
        addr: &ListenAddr,
        fleet: Arc<Fleet>,
        backends: BackendFactory,
        autoscaler: Option<AutoscalerHandle>,
        cfg: NetServerConfig,
    ) -> Result<NetServer> {
        let (listener, local, sock_path) = match addr {
            ListenAddr::Tcp(a) => {
                let l = TcpListener::bind(a.as_str())
                    .with_context(|| format!("binding tcp listener on {a}"))?;
                let resolved = l
                    .local_addr()
                    .map(|sa| sa.to_string())
                    .unwrap_or_else(|_| a.clone());
                (Listener::Tcp(l), ListenAddr::Tcp(resolved), None)
            }
            ListenAddr::Unix(p) => {
                // Connect-probe a pre-existing socket: refuse to replace
                // a live server, but clean up after a dead one.
                if p.exists() {
                    if UnixStream::connect(p).is_ok() {
                        return Err(anyhow!(
                            "unix socket {} already has a listening server",
                            p.display()
                        ));
                    }
                    std::fs::remove_file(p)
                        .with_context(|| format!("removing stale socket {}", p.display()))?;
                }
                let l = UnixListener::bind(p)
                    .with_context(|| format!("binding unix listener on {}", p.display()))?;
                (
                    Listener::Unix(l, p.clone()),
                    ListenAddr::Unix(p.clone()),
                    Some(p.clone()),
                )
            }
        };
        match &listener {
            Listener::Tcp(l) => l.set_nonblocking(true)?,
            Listener::Unix(l, _) => l.set_nonblocking(true)?,
        }
        let shared = Arc::new(ServerShared {
            controller: fleet.controller(),
            fleet,
            backends,
            autoscaler,
            cfg,
            closed: AtomicBool::new(false),
            open_tickets: AtomicU64::new(0),
            conns: AtomicUsize::new(0),
        });
        let accept = {
            let shared = Arc::clone(&shared);
            thread::Builder::new()
                .name("net-accept".into())
                .spawn(move || accept_loop(listener, shared))
                .context("spawning accept loop")?
        };
        Ok(NetServer {
            shared,
            accept: Some(accept),
            local: local.clone(),
            sock_path,
        })
    }

    /// The bound address — for TCP this has the real port even when the
    /// caller asked for `:0`.
    pub fn local_addr(&self) -> &ListenAddr {
        &self.local
    }

    /// Tickets handed to remote callers that have not resolved yet.
    pub fn open_tickets(&self) -> u64 {
        self.shared.open_tickets.load(Ordering::SeqCst)
    }

    /// Live connections.
    pub fn connections(&self) -> usize {
        self.shared.conns.load(Ordering::SeqCst)
    }

    /// Graceful shutdown: refuse new submits, stop accepting, wait
    /// (bounded by `drain_timeout`) for outstanding remote tickets to
    /// resolve, then tear down connections. The fleet itself is NOT shut
    /// down — the caller still owns its `Arc<Fleet>`.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        if self.shared.closed.swap(true, Ordering::SeqCst) {
            return;
        }
        let drain_deadline = Instant::now() + self.shared.cfg.drain_timeout;
        while self.shared.open_tickets.load(Ordering::SeqCst) > 0
            && Instant::now() < drain_deadline
        {
            thread::sleep(Duration::from_millis(5));
        }
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // Readers notice `closed` at their next read-timeout tick.
        let conn_deadline =
            Instant::now() + self.shared.cfg.read_timeout * 4 + Duration::from_secs(1);
        while self.shared.conns.load(Ordering::SeqCst) > 0 && Instant::now() < conn_deadline {
            thread::sleep(Duration::from_millis(5));
        }
        if let Some(p) = self.sock_path.take() {
            let _ = std::fs::remove_file(p);
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn accept_loop(listener: Listener, shared: Arc<ServerShared>) {
    loop {
        if shared.closed.load(Ordering::SeqCst) {
            return;
        }
        let accepted = match &listener {
            Listener::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s)),
            Listener::Unix(l, _) => l.accept().map(|(s, _)| Stream::Unix(s)),
        };
        match accepted {
            Ok(stream) => {
                if shared.conns.load(Ordering::SeqCst) >= shared.cfg.max_conns {
                    refuse_connection(stream, shared.cfg.max_conns);
                    continue;
                }
                shared.conns.fetch_add(1, Ordering::SeqCst);
                let guard = ConnGuard(Arc::clone(&shared));
                // If the spawn fails, the closure (and the guard inside
                // it) is dropped right here, settling the count.
                let _ = thread::Builder::new()
                    .name("net-conn".into())
                    .spawn(move || {
                        serve_connection(stream, &guard.0);
                    });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(5));
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => {
                // Listener died under us; stop accepting. Existing
                // connections keep running until shutdown.
                return;
            }
        }
    }
}

/// Decrements the live-connection count when dropped — including when
/// the connection thread unwinds from a panic — so a crashed connection
/// can never wedge the accept loop's `max_conns` budget or stall
/// shutdown's connection drain.
struct ConnGuard(Arc<ServerShared>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.conns.fetch_sub(1, Ordering::SeqCst);
    }
}

/// A connection's outstanding tickets. On drop — clean exit or panic
/// unwinding — tickets the client never collected are subtracted from
/// the server-wide open-ticket count, so graceful shutdown is not held
/// hostage by a vanished (or crashed) connection.
struct TicketLedger<'a> {
    shared: &'a Arc<ServerShared>,
    tickets: HashMap<u64, Ticket>,
}

impl Drop for TicketLedger<'_> {
    fn drop(&mut self) {
        let abandoned = self.tickets.len() as u64;
        if abandoned > 0 {
            self.shared.open_tickets.fetch_sub(abandoned, Ordering::SeqCst);
        }
    }
}

/// Over-cap connection: best-effort typed error frame, then close.
fn refuse_connection(mut stream: Stream, cap: usize) {
    let frame = ResponseFrame::err(
        0,
        WireError::new(
            WireErrorKind::Saturated,
            format!("server connection limit ({cap}) reached"),
        ),
    );
    let _ = stream.write_all(frame.to_line().as_bytes());
    let _ = stream.flush();
    stream.shutdown_both();
}

/// Per-connection reader: parse frames, execute verbs, push responses
/// to the writer thread. Owns the connection's outstanding tickets.
fn serve_connection(stream: Stream, shared: &Arc<ServerShared>) {
    let (read_half, write_half) = match stream.split(shared.cfg.read_timeout) {
        Ok(halves) => halves,
        Err(_) => {
            stream.shutdown_both();
            return;
        }
    };
    let (tx, rx) = mpsc::channel::<String>();
    let writer = thread::Builder::new()
        .name("net-write".into())
        .spawn(move || writer_loop(write_half, rx));
    let writer = match writer {
        Ok(w) => w,
        Err(_) => {
            stream.shutdown_both();
            return;
        }
    };

    let mut reader = BufReader::new(read_half);
    let mut ledger = TicketLedger {
        shared,
        tickets: HashMap::new(),
    };
    let mut last_activity = Instant::now();
    loop {
        if shared.closed.load(Ordering::SeqCst) && ledger.tickets.is_empty() {
            break;
        }
        let line = match read_frame_line(&mut reader, shared.cfg.max_line_bytes) {
            Ok(Some(line)) => line,
            Ok(None) => break,
            Err(ProtocolError::Timeout) => {
                if last_activity.elapsed() > shared.cfg.idle_timeout {
                    break;
                }
                continue;
            }
            Err(e @ (ProtocolError::Oversized { .. } | ProtocolError::Truncated)) => {
                let _ = tx.send(
                    ResponseFrame::err(0, WireError::new(WireErrorKind::Protocol, e.to_string()))
                        .to_line(),
                );
                break;
            }
            Err(_) => break,
        };
        last_activity = Instant::now();
        let frame = match RequestFrame::parse(&line) {
            Ok(f) => f,
            Err(e @ ProtocolError::Version { .. }) => {
                let _ = tx.send(
                    ResponseFrame::err(0, WireError::new(WireErrorKind::Protocol, e.to_string()))
                        .to_line(),
                );
                break;
            }
            Err(e) => {
                // One bad frame does not corrupt line framing; report it
                // and keep the connection.
                let _ = tx.send(
                    ResponseFrame::err(0, WireError::new(WireErrorKind::Protocol, e.to_string()))
                        .to_line(),
                );
                continue;
            }
        };
        let response = dispatch(shared, &mut ledger.tickets, frame);
        if tx.send(response.to_line()).is_err() {
            break;
        }
    }
    // Settles any tickets the client never collected via its Drop.
    drop(ledger);
    drop(tx); // writer drains then exits
    let _ = writer.join();
    stream.shutdown_both();
}

fn writer_loop(mut w: Stream, rx: mpsc::Receiver<String>) {
    while let Ok(line) = rx.recv() {
        if w.write_all(line.as_bytes()).is_err() || w.flush().is_err() {
            return;
        }
    }
}

fn ok(id: u64, body: Json) -> ResponseFrame {
    ResponseFrame::ok(id, body)
}

fn err(id: u64, kind: WireErrorKind, msg: impl Into<String>) -> ResponseFrame {
    ResponseFrame::err(id, WireError::new(kind, msg))
}

/// Execute one verb against the fleet/controller.
fn dispatch(
    shared: &Arc<ServerShared>,
    tickets: &mut HashMap<u64, Ticket>,
    frame: RequestFrame,
) -> ResponseFrame {
    let id = frame.id;
    let p = &frame.payload;
    match frame.verb {
        Verb::Submit => {
            if shared.closed.load(Ordering::SeqCst) {
                return ResponseFrame::err(
                    id,
                    WireError::from_submit(&SubmitError::ShuttingDown),
                );
            }
            let req = match protocol::decode_submit(p) {
                Ok(r) => r,
                Err(e) => return err(id, WireErrorKind::Protocol, e.to_string()),
            };
            match shared.fleet.submit(req) {
                Ok(ticket) => {
                    shared.open_tickets.fetch_add(1, Ordering::SeqCst);
                    let body = Json::obj().set("ticket", ticket.id);
                    let body = match ticket.device_id() {
                        Some(d) => body.set("device", d),
                        None => body,
                    };
                    tickets.insert(ticket.id, ticket);
                    ok(id, body)
                }
                Err(e) => ResponseFrame::err(id, WireError::from_submit(&e)),
            }
        }
        Verb::Wait => {
            let Some(tid) = p.get("ticket").and_then(Json::as_u64) else {
                return err(id, WireErrorKind::Protocol, "wait missing 'ticket'");
            };
            // Per-call budget, capped so one call never outlives the
            // idle timeout; the client loops until done. NaN (which
            // clamp passes through) falls back to the default.
            let budget_ms = p
                .get("timeout_ms")
                .and_then(Json::as_f64)
                .filter(|ms| ms.is_finite())
                .unwrap_or(1000.0)
                .clamp(0.0, 5000.0);
            let Some(ticket) = tickets.remove(&tid) else {
                return err(id, WireErrorKind::NotFound, format!("no ticket {tid}"));
            };
            let deadline = Instant::now() + Duration::from_secs_f64(budget_ms / 1e3);
            loop {
                let left = deadline.saturating_duration_since(Instant::now());
                let step = left.min(Duration::from_millis(100));
                match ticket.wait_timeout(step) {
                    Ok(Some(img)) => {
                        shared.open_tickets.fetch_sub(1, Ordering::SeqCst);
                        return ok(
                            id,
                            Json::obj()
                                .set("done", true)
                                .set("image", protocol::encode_image(&img)),
                        );
                    }
                    Ok(None) => {
                        if Instant::now() >= deadline {
                            tickets.insert(tid, ticket);
                            return ok(id, Json::obj().set("done", false));
                        }
                    }
                    Err(e) => {
                        shared.open_tickets.fetch_sub(1, Ordering::SeqCst);
                        return err(id, WireErrorKind::Failed, format!("{e:#}"));
                    }
                }
            }
        }
        Verb::TryWait => {
            let Some(tid) = p.get("ticket").and_then(Json::as_u64) else {
                return err(id, WireErrorKind::Protocol, "try_wait missing 'ticket'");
            };
            let Some(ticket) = tickets.get(&tid) else {
                return err(id, WireErrorKind::NotFound, format!("no ticket {tid}"));
            };
            match ticket.try_wait() {
                Ok(Some(img)) => {
                    let body = Json::obj()
                        .set("done", true)
                        .set("image", protocol::encode_image(&img));
                    tickets.remove(&tid);
                    shared.open_tickets.fetch_sub(1, Ordering::SeqCst);
                    ok(id, body)
                }
                Ok(None) => ok(id, Json::obj().set("done", false)),
                Err(e) => {
                    tickets.remove(&tid);
                    shared.open_tickets.fetch_sub(1, Ordering::SeqCst);
                    err(id, WireErrorKind::Failed, format!("{e:#}"))
                }
            }
        }
        Verb::Cancel => {
            let Some(tid) = p.get("ticket").and_then(Json::as_u64) else {
                return err(id, WireErrorKind::Protocol, "cancel missing 'ticket'");
            };
            let Some(ticket) = tickets.get(&tid) else {
                return err(id, WireErrorKind::NotFound, format!("no ticket {tid}"));
            };
            ticket.cancel();
            // The ticket stays registered: a later wait/try_wait
            // observes the cancelled resolution and settles the count.
            ok(id, Json::obj().set("cancelled", true))
        }
        Verb::Topology => ok(id, encode_topology(&shared.controller.topology())),
        Verb::AddMember => {
            let Some(dev_id) = p.get("device").and_then(Json::as_str) else {
                return err(id, WireErrorKind::Protocol, "add_member missing 'device'");
            };
            let Some(desc) = crate::device::find_device(dev_id) else {
                return err(
                    id,
                    WireErrorKind::NotFound,
                    format!("no device '{dev_id}' in the registry"),
                );
            };
            let policy = match p.get("policy") {
                Some(pp) => match protocol::decode_policy(pp) {
                    Ok(pol) => pol,
                    Err(e) => return err(id, WireErrorKind::Protocol, e.to_string()),
                },
                None => crate::coordinator::TilePolicy::PortableFallback,
            };
            let backend = (shared.backends)(&desc);
            match shared.controller.add_member(desc, backend, policy) {
                Ok(member) => ok(
                    id,
                    Json::obj()
                        .set("member", member)
                        .set("epoch", shared.controller.epoch()),
                ),
                Err(e) => err(id, WireErrorKind::Internal, format!("{e:#}")),
            }
        }
        Verb::RemoveMember => {
            let Some(dev_id) = p.get("device").and_then(Json::as_str) else {
                return err(id, WireErrorKind::Protocol, "remove_member missing 'device'");
            };
            let mode = match p.get("mode").and_then(Json::as_str) {
                None => crate::coordinator::DrainMode::Graceful,
                Some(m) => match protocol::parse_drain_mode(m) {
                    Ok(m) => m,
                    Err(e) => return err(id, WireErrorKind::Protocol, e.to_string()),
                },
            };
            match shared.controller.remove_member(dev_id, mode) {
                Ok(()) => ok(id, Json::obj().set("epoch", shared.controller.epoch())),
                Err(e) => err(id, WireErrorKind::NotFound, format!("{e:#}")),
            }
        }
        Verb::Drain => {
            let Some(dev_id) = p.get("device").and_then(Json::as_str) else {
                return err(id, WireErrorKind::Protocol, "drain missing 'device'");
            };
            match shared.controller.drain(dev_id) {
                Ok(()) => ok(id, Json::obj().set("epoch", shared.controller.epoch())),
                Err(e) => err(id, WireErrorKind::NotFound, format!("{e:#}")),
            }
        }
        Verb::Retune => {
            let Some(dev_id) = p.get("device").and_then(Json::as_str) else {
                return err(id, WireErrorKind::Protocol, "retune missing 'device'");
            };
            let Some(oj) = p.get("outcome") else {
                return err(id, WireErrorKind::Protocol, "retune missing 'outcome'");
            };
            let outcome = match crate::autotuner::TuningOutcome::from_json(oj) {
                Ok(o) => o,
                Err(e) => return err(id, WireErrorKind::Protocol, format!("{e:#}")),
            };
            match shared.controller.retune(dev_id, &outcome) {
                Ok(tile) => ok(
                    id,
                    Json::obj().set(
                        "tile",
                        match tile {
                            Some(t) => Json::Str(t.label()),
                            None => Json::Null,
                        },
                    ),
                ),
                Err(e) => err(id, WireErrorKind::NotFound, format!("{e:#}")),
            }
        }
        Verb::SetScheduler => {
            let Some(name) = p.get("name").and_then(Json::as_str) else {
                return err(id, WireErrorKind::Protocol, "set_scheduler missing 'name'");
            };
            match shared.controller.set_scheduler_by_name(name) {
                Ok(()) => ok(id, Json::obj().set("ok", true)),
                Err(e) => err(id, WireErrorKind::Protocol, format!("{e:#}")),
            }
        }
        Verb::SetAdmission => {
            let Some(name) = p.get("name").and_then(Json::as_str) else {
                return err(id, WireErrorKind::Protocol, "set_admission missing 'name'");
            };
            let timeout_ms = p.get("timeout_ms").and_then(Json::as_f64).unwrap_or(50.0);
            let timeout = match protocol::duration_from_ms(timeout_ms, "timeout_ms") {
                Ok(t) => t,
                Err(e) => return err(id, WireErrorKind::Protocol, e.to_string()),
            };
            match shared.controller.set_admission_by_name(name, timeout) {
                Ok(()) => ok(id, Json::obj().set("ok", true)),
                Err(e) => err(id, WireErrorKind::Protocol, format!("{e:#}")),
            }
        }
        Verb::SetStealConfig => {
            let Some(enabled) = p.get("enabled").and_then(Json::as_bool) else {
                return err(
                    id,
                    WireErrorKind::Protocol,
                    "set_steal_config missing 'enabled'",
                );
            };
            let Some(threshold) = p.get("threshold").and_then(Json::as_u64) else {
                return err(
                    id,
                    WireErrorKind::Protocol,
                    "set_steal_config missing 'threshold'",
                );
            };
            match shared
                .controller
                .set_steal_config(enabled, threshold as usize)
            {
                Ok(()) => ok(id, Json::obj().set("ok", true)),
                Err(e) => err(id, WireErrorKind::Internal, format!("{e:#}")),
            }
        }
        Verb::Stats => ok(id, WireStats::of(&shared.fleet.stats()).to_json()),
        Verb::Autoscaler => match &shared.autoscaler {
            Some(h) => ok(id, AutoscalerDesc::of(&h.view()).to_json()),
            None => err(id, WireErrorKind::NotFound, "no autoscaler running"),
        },
        Verb::SetAutoscaler => {
            let Some(h) = &shared.autoscaler else {
                return err(id, WireErrorKind::NotFound, "no autoscaler running");
            };
            let update = match protocol::decode_autoscaler_update(p) {
                Ok(u) => u,
                Err(e) => return err(id, WireErrorKind::Protocol, e.to_string()),
            };
            match h.apply(&update) {
                Ok(()) => ok(id, AutoscalerDesc::of(&h.view()).to_json()),
                Err(e) => err(id, WireErrorKind::Protocol, format!("{e:#}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn listen_addr_parses_and_displays() {
        assert_eq!(
            ListenAddr::parse("127.0.0.1:7441").unwrap(),
            ListenAddr::Tcp("127.0.0.1:7441".into())
        );
        assert_eq!(
            ListenAddr::parse("unix:/tmp/tilekit.sock").unwrap(),
            ListenAddr::Unix(PathBuf::from("/tmp/tilekit.sock"))
        );
        assert_eq!(
            ListenAddr::parse("unix:/tmp/x.sock").unwrap().to_string(),
            "unix:/tmp/x.sock"
        );
        assert_eq!(
            ListenAddr::parse("[::1]:0").unwrap().to_string(),
            "[::1]:0"
        );
        for bad in ["", "noport", ":7441", "host:", "host:notaport", "host:99999", "unix:"] {
            assert!(ListenAddr::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }
}
