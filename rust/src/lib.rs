//! # tilekit
//!
//! A production-grade reproduction of *"Tiling for Performance Tuning on
//! Different Models of GPUs"* (Chang Xu, Steven R. Kirk, Samantha Jenkins,
//! CS.DC 2010).
//!
//! The paper studies how CUDA thread-block **tiling dimensions** interact
//! with the **compute capability** of different GPU models (GTX 260 vs
//! GeForce 8800 GTS) for a bilinear image-interpolation kernel — and
//! concludes that a tile tuned on one model "is not always a good
//! solution when executed on other GPU models". This crate rebuilds the
//! whole study as a three-layer system and turns that conclusion into a
//! first-class, re-runnable operation:
//!
//! * **L3 (this crate)** — a compute-capability-aware GPU timing
//!   simulator ([`sim`]), a CUDA-style occupancy calculator ([`tiling`]),
//!   a **strategy-driven tuning API** ([`autotuner`]): pluggable
//!   [`CostModel`](autotuner::CostModel)s, search strategies
//!   (exhaustive / coordinate descent / persistent-cache decorator), a
//!   [`TuningSession`](autotuner::TuningSession) builder producing
//!   serializable [`TuningOutcome`](autotuner::TuningOutcome)s, and
//!   portable (worst-case-GPU) selection — plus a **fleet-aware**
//!   image-resize serving system ([`coordinator`]), split into a data
//!   plane (a [`Fleet`](coordinator::Fleet) of device members whose
//!   routers consume tuning outcomes through a
//!   [`TilePolicy`](coordinator::TilePolicy) — each device serves
//!   through its own tuned tile — scheduled per typed
//!   [`Request`](coordinator::Request) by a pluggable
//!   [`Scheduler`](coordinator::Scheduler) under a pluggable
//!   [`AdmissionPolicy`](coordinator::AdmissionPolicy)) and a typed
//!   control plane (a [`FleetController`](coordinator::FleetController)
//!   for elastic membership, live reconfiguration, and tuned-tile hot
//!   swaps, driven in the background by the
//!   [`RetuneDaemon`](coordinator::RetuneDaemon)), executing
//!   AOT-compiled JAX/Pallas artifacts through PJRT ([`runtime`]).
//!   The whole fleet is also reachable **out of process** via [`net`]:
//!   a versioned wire protocol (line-delimited JSON headers; protocol
//!   v2 negotiates binary image payloads on connect) served by
//!   [`NetServer`](net::NetServer) (`tilekit serve --listen`), consumed
//!   by the pipelining, auto-reconnecting
//!   [`FleetClient`](net::FleetClient), and scaled out by a
//!   consistent-hash [`FrontTier`](net::FrontTier) over N fleet
//!   processes (`tilekit front --shards`). The [`ops`] traits
//!   ([`FleetOps`](ops::FleetOps) / [`ControlOps`](ops::ControlOps))
//!   make the two transports interchangeable to callers.
//! * **L2 (build time)** — `python/compile/model.py`, a JAX resize graph.
//! * **L1 (build time)** — `python/compile/kernels/*.py`, Pallas kernels
//!   whose `BlockSpec` output tile plays the role of the CUDA block shape.
//!
//! The tuning flow end to end:
//!
//! ```no_run
//! use tilekit::autotuner::{CoordinateDescent, SimCostModel, TuningSession};
//! use tilekit::coordinator::TilePolicy;
//!
//! let outcome = TuningSession::new(SimCostModel)
//!     .scale(8)
//!     .strategy(CoordinateDescent::default())
//!     .run()?;
//! // Route each serving device to its own tuned tile:
//! let policy = TilePolicy::PerDevice(outcome);
//! # let _ = policy;
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! The environment is fully offline, so foundational substrates that would
//! normally come from crates.io are implemented in-tree: [`codec`] (JSON +
//! TOML subset), [`cli`], [`exec`] (thread pool), [`bench`] (benchmark
//! harness), [`prop`] (property-based testing), and [`analysis`] (the
//! `tilekit analyze` invariant analyzer that machine-checks the fleet's
//! concurrency and wire-safety contracts). The `anyhow` and `xla`
//! dependencies are vendored under `rust/vendor/`.
//!
//! Start with [`device::registry`] and [`autotuner`] (its module docs
//! include a migration guide from the old `sweep`/`portable_tile` free
//! functions), or run `tilekit tune` / `tilekit sweep --fig3` to
//! regenerate the paper's headline results.

pub mod analysis;
pub mod autotuner;
pub mod bench;
pub mod cli;
pub mod codec;
pub mod config;
pub mod coordinator;
pub mod device;
pub mod exec;
pub mod image;
pub mod metrics;
pub mod net;
pub mod ops;
pub mod prop;
pub mod runtime;
pub mod sim;
pub mod tiling;
pub mod util;
pub mod workload;
