//! # tilekit
//!
//! A production-grade reproduction of *"Tiling for Performance Tuning on
//! Different Models of GPUs"* (Chang Xu, Steven R. Kirk, Samantha Jenkins,
//! CS.DC 2010).
//!
//! The paper studies how CUDA thread-block **tiling dimensions** interact
//! with the **compute capability** of different GPU models (GTX 260 vs
//! GeForce 8800 GTS) for a bilinear image-interpolation kernel. This crate
//! rebuilds the whole study as a three-layer system:
//!
//! * **L3 (this crate)** — a compute-capability-aware GPU timing simulator
//!   ([`sim`]), a CUDA-style occupancy calculator ([`tiling`]), a tiling
//!   autotuner with portable (worst-case-GPU) selection ([`autotuner`]),
//!   and an image-resize serving system ([`coordinator`]) that executes
//!   AOT-compiled JAX/Pallas artifacts through PJRT ([`runtime`]).
//! * **L2 (build time)** — `python/compile/model.py`, a JAX resize graph.
//! * **L1 (build time)** — `python/compile/kernels/*.py`, Pallas kernels
//!   whose `BlockSpec` output tile plays the role of the CUDA block shape.
//!
//! The environment is fully offline, so foundational substrates that would
//! normally come from crates.io are implemented in-tree: [`codec`] (JSON +
//! TOML subset), [`cli`], [`exec`] (thread pool), [`bench`] (benchmark
//! harness), and [`prop`] (property-based testing).
//!
//! Start with [`device::registry`] and [`sim::engine`], or run
//! `tilekit sweep --fig3` to regenerate the paper's headline figure.

pub mod autotuner;
pub mod bench;
pub mod cli;
pub mod codec;
pub mod config;
pub mod coordinator;
pub mod device;
pub mod exec;
pub mod image;
pub mod metrics;
pub mod prop;
pub mod runtime;
pub mod sim;
pub mod tiling;
pub mod util;
pub mod workload;
