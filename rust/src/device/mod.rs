//! GPU device models: compute-capability feature sets and per-device
//! descriptors (the paper's Table I), plus a registry of known devices.
//!
//! Everything downstream — the occupancy calculator ([`crate::tiling`]),
//! the timing simulator ([`crate::sim`]), and the autotuner — is
//! parameterized by a [`DeviceDescriptor`], so adding a new GPU model is a
//! single registry entry (or a `[[device]]` block in a TOML config).

pub mod capability;
pub mod descriptor;
pub mod registry;

pub use capability::{CoalescingModel, ComputeCapability};
pub use descriptor::DeviceDescriptor;
pub use registry::{builtin_devices, find_device, paper_pair, table1};
