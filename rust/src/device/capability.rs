//! CUDA compute-capability feature sets (versions 1.0 – 2.0).
//!
//! The paper's central observation is that tiling tuned on one compute
//! capability does not transfer to another; the capability version fixes
//! the *architectural limits* (max threads/warps/blocks per SM, register
//! file size, block dimension caps) and the *global-memory coalescing
//! rules* that the simulator's memory model implements.
//!
//! Sources: NVIDIA CUDA Programming Guide 2.1 (the version the paper
//! used), Appendix A; GTX 200 architectural brief.

use std::fmt;

/// How the device coalesces global-memory accesses of a half-warp/warp.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CoalescingModel {
    /// cc 1.0/1.1: a half-warp (16 threads) coalesces into ONE transaction
    /// only if threads access a contiguous, aligned 64B/128B segment in
    /// strict thread-order; any deviation serializes into 16 separate
    /// transactions.
    StrictHalfWarp,
    /// cc 1.2/1.3: the hardware issues the minimal set of 32/64/128-byte
    /// segment transactions covering the addresses touched by a half-warp;
    /// misalignment degrades gracefully instead of serializing.
    SegmentedHalfWarp,
    /// cc 2.x (Fermi): per-warp transactions through an L1 cache with
    /// 128-byte lines. Included for the "newer models keep shifting the
    /// optimum" extension experiments.
    CachedWarp,
}

impl CoalescingModel {
    /// Human-readable label used in reports.
    pub fn label(self) -> &'static str {
        match self {
            CoalescingModel::StrictHalfWarp => "strict half-warp (cc1.0/1.1)",
            CoalescingModel::SegmentedHalfWarp => "segmented half-warp (cc1.2/1.3)",
            CoalescingModel::CachedWarp => "cached warp (cc2.x)",
        }
    }
}

/// Architectural limits of one compute-capability version.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ComputeCapability {
    pub major: u8,
    pub minor: u8,
    /// Maximum resident threads per SM (768 on cc1.0/1.1, 1024 on 1.2/1.3,
    /// 1536 on 2.0).
    pub max_threads_per_sm: u32,
    /// Maximum resident warps per SM (24 / 32 / 48).
    pub max_warps_per_sm: u32,
    /// Maximum resident blocks per SM (8 for all cc 1.x/2.x).
    pub max_blocks_per_sm: u32,
    /// 32-bit registers per SM (8K / 16K / 32K).
    pub registers_per_sm: u32,
    /// Shared memory per SM in bytes (16 KiB on cc1.x, 48 KiB on 2.0).
    pub shared_mem_per_sm: u32,
    /// Maximum threads per block (512 on cc1.x, 1024 on 2.0).
    pub max_threads_per_block: u32,
    /// Maximum block dimensions (x, y, z): (512,512,64) on cc1.x.
    pub max_block_dim: (u32, u32, u32),
    /// Maximum grid dimensions (x, y): 65535 each on cc1.x/2.x.
    pub max_grid_dim: (u32, u32),
    /// Warp size (32 for every CUDA architecture covered).
    pub warp_size: u32,
    /// Register allocation granularity per block (256 on cc1.0/1.1,
    /// 512 on cc1.2/1.3 — registers round up to this multiple).
    pub register_alloc_unit: u32,
    /// Coalescing behaviour.
    pub coalescing: CoalescingModel,
    /// SPs per SM (8 on cc1.x, 32 on cc2.0).
    pub sps_per_sm: u32,
}

impl ComputeCapability {
    /// cc 1.0 — GeForce 8800 GTS/GTX generation (G80).
    pub const CC_1_0: ComputeCapability = ComputeCapability {
        major: 1,
        minor: 0,
        max_threads_per_sm: 768,
        max_warps_per_sm: 24,
        max_blocks_per_sm: 8,
        registers_per_sm: 8192,
        shared_mem_per_sm: 16 * 1024,
        max_threads_per_block: 512,
        max_block_dim: (512, 512, 64),
        max_grid_dim: (65535, 65535),
        warp_size: 32,
        register_alloc_unit: 256,
        coalescing: CoalescingModel::StrictHalfWarp,
        sps_per_sm: 8,
    };

    /// cc 1.1 — G84/G86/G92 (e.g. 9600 GT). Same limits as 1.0 plus
    /// global atomics (not modeled).
    pub const CC_1_1: ComputeCapability = ComputeCapability {
        minor: 1,
        ..ComputeCapability::CC_1_0
    };

    /// cc 1.2 — GT21x. 1024 threads / 32 warps / 16K registers, relaxed
    /// coalescing.
    pub const CC_1_2: ComputeCapability = ComputeCapability {
        major: 1,
        minor: 2,
        max_threads_per_sm: 1024,
        max_warps_per_sm: 32,
        max_blocks_per_sm: 8,
        registers_per_sm: 16384,
        shared_mem_per_sm: 16 * 1024,
        max_threads_per_block: 512,
        max_block_dim: (512, 512, 64),
        max_grid_dim: (65535, 65535),
        warp_size: 32,
        register_alloc_unit: 512,
        coalescing: CoalescingModel::SegmentedHalfWarp,
        sps_per_sm: 8,
    };

    /// cc 1.3 — GT200 (GTX 260/280, Tesla C1060). As 1.2 + double support.
    pub const CC_1_3: ComputeCapability = ComputeCapability {
        minor: 3,
        ..ComputeCapability::CC_1_2
    };

    /// cc 2.0 — Fermi (the "recently announced" architecture in the
    /// paper's introduction). Used by the forward-looking ablation.
    pub const CC_2_0: ComputeCapability = ComputeCapability {
        major: 2,
        minor: 0,
        max_threads_per_sm: 1536,
        max_warps_per_sm: 48,
        max_blocks_per_sm: 8,
        registers_per_sm: 32768,
        shared_mem_per_sm: 48 * 1024,
        max_threads_per_block: 1024,
        max_block_dim: (1024, 1024, 64),
        max_grid_dim: (65535, 65535),
        warp_size: 32,
        register_alloc_unit: 64,
        coalescing: CoalescingModel::CachedWarp,
        sps_per_sm: 32,
    };

    /// Look up a capability by `major.minor` string, e.g. `"1.3"`.
    pub fn by_version(v: &str) -> Option<ComputeCapability> {
        match v {
            "1.0" => Some(Self::CC_1_0),
            "1.1" => Some(Self::CC_1_1),
            "1.2" => Some(Self::CC_1_2),
            "1.3" => Some(Self::CC_1_3),
            "2.0" => Some(Self::CC_2_0),
            _ => None,
        }
    }

    /// `major.minor` as a string.
    pub fn version(&self) -> String {
        format!("{}.{}", self.major, self.minor)
    }

    /// Sanity invariant: threads = warps × warp_size must hold for every
    /// real capability (checked by tests and proptests).
    pub fn is_consistent(&self) -> bool {
        self.max_threads_per_sm == self.max_warps_per_sm * self.warp_size
            && self.max_threads_per_block <= self.max_threads_per_sm
            && self.warp_size == 32
    }
}

impl fmt::Display for ComputeCapability {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "cc{}.{}", self.major, self.minor)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALL: [ComputeCapability; 5] = [
        ComputeCapability::CC_1_0,
        ComputeCapability::CC_1_1,
        ComputeCapability::CC_1_2,
        ComputeCapability::CC_1_3,
        ComputeCapability::CC_2_0,
    ];

    #[test]
    fn all_versions_consistent() {
        for cc in ALL {
            assert!(cc.is_consistent(), "{cc} inconsistent");
        }
    }

    #[test]
    fn paper_table1_limits() {
        // Table I row "active warps per SM": 32 vs 24.
        assert_eq!(ComputeCapability::CC_1_3.max_warps_per_sm, 32);
        assert_eq!(ComputeCapability::CC_1_0.max_warps_per_sm, 24);
        // Table I row "active threads per SM": 1024 vs 768.
        assert_eq!(ComputeCapability::CC_1_3.max_threads_per_sm, 1024);
        assert_eq!(ComputeCapability::CC_1_0.max_threads_per_sm, 768);
        // Table I row "number of register per SM": 16384 vs 8192.
        assert_eq!(ComputeCapability::CC_1_3.registers_per_sm, 16384);
        assert_eq!(ComputeCapability::CC_1_0.registers_per_sm, 8192);
    }

    #[test]
    fn block_dim_limits_match_guide() {
        // §II.A: "a thread block has the maximum dimensions sizes of
        // 512, 512 and 62 [64]" and "maximum number of threads in one
        // block is limited to 512" for cc1.3.
        let cc = ComputeCapability::CC_1_3;
        assert_eq!(cc.max_block_dim, (512, 512, 64));
        assert_eq!(cc.max_threads_per_block, 512);
        assert_eq!(cc.max_grid_dim, (65535, 65535));
    }

    #[test]
    fn version_round_trip() {
        for cc in ALL {
            if cc.minor == 1 && cc.major == 1 {
                continue; // 1.1 shares limits with 1.0 but is distinct
            }
            let again = ComputeCapability::by_version(&cc.version()).unwrap();
            assert_eq!(again, cc);
        }
        assert!(ComputeCapability::by_version("9.9").is_none());
    }

    #[test]
    fn coalescing_progression() {
        assert_eq!(
            ComputeCapability::CC_1_0.coalescing,
            CoalescingModel::StrictHalfWarp
        );
        assert_eq!(
            ComputeCapability::CC_1_3.coalescing,
            CoalescingModel::SegmentedHalfWarp
        );
        assert_eq!(
            ComputeCapability::CC_2_0.coalescing,
            CoalescingModel::CachedWarp
        );
    }
}
