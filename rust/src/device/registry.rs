//! Built-in device registry: the paper's two testbed GPUs, several
//! contemporaries for the cross-model ablations, and the synthetic G1/G2
//! pair from the paper's §IV.C extreme example.

use super::capability::ComputeCapability;
use super::descriptor::DeviceDescriptor;
use crate::util::text::Table;

fn dev(
    id: &str,
    name: &str,
    cc: ComputeCapability,
    sm_count: u32,
    sp_clock_mhz: f64,
    mem_clock_mhz: f64,
    mem_bus_bits: u32,
    global_mem_mib: u32,
) -> DeviceDescriptor {
    DeviceDescriptor {
        id: id.into(),
        name: name.into(),
        cc,
        sm_count,
        sp_clock_mhz,
        mem_clock_mhz,
        mem_bus_bits,
        global_mem_mib,
        mem_latency_cycles: 500.0,
        row_switch_cycles: 20.0,
    }
}

/// All built-in devices. The first two are the paper's testbed (Table I).
pub fn builtin_devices() -> Vec<DeviceDescriptor> {
    vec![
        // ---- the paper's testbed -----------------------------------------
        dev(
            "gtx260",
            "NVIDIA GeForce GTX 260",
            ComputeCapability::CC_1_3,
            24,     // Table I: 24 SMs, 192 SPs
            1242.0, // shader clock
            1998.0, // effective memory clock
            448,
            896, // "1G" in Table I is marketing rounding of 896 MiB
        ),
        dev(
            "8800gts",
            "NVIDIA GeForce 8800 GTS",
            ComputeCapability::CC_1_0,
            12,     // Table I: 12 SMs, 96 SPs
            1188.0, // shader clock (G80 GTS)
            1584.0, // effective memory clock
            320,
            320, // Table I: 320 MB
        ),
        // ---- contemporaries for the cross-model ablation ------------------
        dev(
            "8800gtx",
            "NVIDIA GeForce 8800 GTX",
            ComputeCapability::CC_1_0,
            16,
            1350.0,
            1800.0,
            384,
            768,
        ),
        dev(
            "9600gt",
            "NVIDIA GeForce 9600 GT",
            ComputeCapability::CC_1_1,
            8,
            1625.0,
            1800.0,
            256,
            512,
        ),
        dev(
            "gtx280",
            "NVIDIA GeForce GTX 280",
            ComputeCapability::CC_1_3,
            30,
            1296.0,
            2214.0,
            512,
            1024,
        ),
        dev(
            "teslac1060",
            "NVIDIA Tesla C1060",
            ComputeCapability::CC_1_3,
            30,
            1296.0,
            1600.0,
            512,
            4096,
        ),
        dev(
            "fermi",
            "NVIDIA Fermi (GF100-class, announced)",
            ComputeCapability::CC_2_0,
            16,
            1401.0,
            3696.0,
            384,
            1536,
        ),
        // ---- §IV.C synthetic extreme pair ---------------------------------
        // "G1 is a GPU with two SMs (16 cores), G2 is a GPU with twenty SMs
        // (160 cores). Each SM can support at most 1024 active threads."
        dev(
            "g1",
            "Synthetic G1 (2 SMs, paper §IV.C)",
            ComputeCapability::CC_1_3,
            2,
            1242.0,
            1998.0,
            448,
            896,
        ),
        dev(
            "g2",
            "Synthetic G2 (20 SMs, paper §IV.C)",
            ComputeCapability::CC_1_3,
            20,
            1242.0,
            1998.0,
            448,
            896,
        ),
    ]
}

/// Find a built-in device by id (case-insensitive).
pub fn find_device(id: &str) -> Option<DeviceDescriptor> {
    let id = id.to_ascii_lowercase();
    builtin_devices().into_iter().find(|d| d.id == id)
}

/// The paper's testbed pair: (GTX 260, GeForce 8800 GTS).
pub fn paper_pair() -> (DeviceDescriptor, DeviceDescriptor) {
    (
        find_device("gtx260").expect("builtin"),
        find_device("8800gts").expect("builtin"),
    )
}

/// Regenerate the paper's Table I ("COMPUTE CAPABILITY OF GTX260 AND
/// GEFORCE 8800") from the registry.
pub fn table1() -> Table {
    let (gtx, gts) = paper_pair();
    let mut t = Table::new(vec!["Features", &gtx.name, &gts.name]);
    let row = |t: &mut Table, label: &str, a: String, b: String| {
        t.row(vec![label.to_string(), a, b]);
    };
    row(
        &mut t,
        "number of register per SM",
        gtx.cc.registers_per_sm.to_string(),
        gts.cc.registers_per_sm.to_string(),
    );
    row(
        &mut t,
        "active warps per SM",
        gtx.cc.max_warps_per_sm.to_string(),
        gts.cc.max_warps_per_sm.to_string(),
    );
    row(
        &mut t,
        "active threads per SM",
        gtx.cc.max_threads_per_sm.to_string(),
        gts.cc.max_threads_per_sm.to_string(),
    );
    row(
        &mut t,
        "total SP",
        gtx.total_sps().to_string(),
        gts.total_sps().to_string(),
    );
    row(
        &mut t,
        "number of SM",
        gtx.sm_count.to_string(),
        gts.sm_count.to_string(),
    );
    row(
        &mut t,
        "global memory",
        format!("{} MiB", gtx.global_mem_mib),
        format!("{} MiB", gts.global_mem_mib),
    );
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_builtins_validate() {
        for d in builtin_devices() {
            d.validate().unwrap_or_else(|e| panic!("{}: {e}", d.id));
        }
    }

    #[test]
    fn ids_unique() {
        let devs = builtin_devices();
        let mut ids: Vec<&str> = devs.iter().map(|d| d.id.as_str()).collect();
        ids.sort_unstable();
        let before = ids.len();
        ids.dedup();
        assert_eq!(ids.len(), before, "duplicate device ids");
    }

    #[test]
    fn paper_pair_matches_table1() {
        let (gtx, gts) = paper_pair();
        // Table I, all six rows:
        assert_eq!(gtx.cc.registers_per_sm, 16384);
        assert_eq!(gts.cc.registers_per_sm, 8192);
        assert_eq!(gtx.cc.max_warps_per_sm, 32);
        assert_eq!(gts.cc.max_warps_per_sm, 24);
        assert_eq!(gtx.cc.max_threads_per_sm, 1024);
        assert_eq!(gts.cc.max_threads_per_sm, 768);
        assert_eq!(gtx.total_sps(), 192);
        assert_eq!(gts.total_sps(), 96);
        assert_eq!(gtx.sm_count, 24);
        assert_eq!(gts.sm_count, 12);
    }

    #[test]
    fn extreme_pair_matches_section_4c() {
        let g1 = find_device("g1").unwrap();
        let g2 = find_device("g2").unwrap();
        assert_eq!(g1.sm_count, 2);
        assert_eq!(g1.total_sps(), 16);
        assert_eq!(g2.sm_count, 20);
        assert_eq!(g2.total_sps(), 160);
        assert_eq!(g1.cc.max_threads_per_sm, 1024);
    }

    #[test]
    fn find_is_case_insensitive_and_total() {
        assert!(find_device("GTX260").is_some());
        assert!(find_device("nope").is_none());
    }

    #[test]
    fn table1_renders_six_rows() {
        let t = table1();
        assert_eq!(t.n_rows(), 6);
        let text = t.render();
        assert!(text.contains("16384"));
        assert!(text.contains("8192"));
        assert!(text.contains("320 MiB"));
    }
}
