//! Per-device descriptors: a compute capability plus the chip-specific
//! parameters (SM count, clocks, memory) — one row of the paper's Table I
//! plus the timing constants the simulator needs.

use super::capability::ComputeCapability;
use crate::codec::toml::TomlTable;
use std::fmt;

/// A concrete GPU model. `cc` carries the architectural limits; the other
/// fields are the chip parameters that differ between models sharing a
/// capability (e.g. GTX 260 vs GTX 280 are both cc1.3 with 24 vs 30 SMs).
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceDescriptor {
    /// Short identifier used on the CLI and in reports (`gtx260`).
    pub id: String,
    /// Marketing name ("NVIDIA GeForce GTX 260").
    pub name: String,
    /// Architectural limits.
    pub cc: ComputeCapability,
    /// Number of streaming multiprocessors.
    pub sm_count: u32,
    /// Shader (SP) clock in MHz.
    pub sp_clock_mhz: f64,
    /// Memory clock in MHz (effective, DDR-doubled).
    pub mem_clock_mhz: f64,
    /// Memory bus width in bits.
    pub mem_bus_bits: u32,
    /// Global memory in MiB.
    pub global_mem_mib: u32,
    /// Approximate DRAM latency in SP-clock cycles (400–600 per the
    /// programming guide; the simulator treats this as the uncontended
    /// round-trip).
    pub mem_latency_cycles: f64,
    /// Extra cost (cycles) charged when a block's access pattern crosses
    /// from one output row to the next and the rows land in different
    /// DRAM pages — scaled by row pitch in the memory model. This is the
    /// Fig. 4 "pointer movement between rows" effect.
    pub row_switch_cycles: f64,
}

impl DeviceDescriptor {
    /// Total SP (core) count = SMs × SPs/SM (Table I row "total SP").
    pub fn total_sps(&self) -> u32 {
        self.sm_count * self.cc.sps_per_sm
    }

    /// Peak memory bandwidth in GiB/s.
    pub fn mem_bandwidth_gib(&self) -> f64 {
        self.mem_clock_mhz * 1e6 * (self.mem_bus_bits as f64 / 8.0) / (1u64 << 30) as f64
    }

    /// Internal consistency (used by proptests and config validation).
    pub fn validate(&self) -> Result<(), String> {
        if self.id.is_empty() || self.name.is_empty() {
            return Err("device id/name must be non-empty".into());
        }
        if self.sm_count == 0 {
            return Err(format!("{}: sm_count must be > 0", self.id));
        }
        if !self.cc.is_consistent() {
            return Err(format!("{}: inconsistent compute capability", self.id));
        }
        if self.sp_clock_mhz <= 0.0 || self.mem_clock_mhz <= 0.0 {
            return Err(format!("{}: clocks must be positive", self.id));
        }
        if self.mem_latency_cycles < 0.0 || self.row_switch_cycles < 0.0 {
            return Err(format!("{}: latencies must be non-negative", self.id));
        }
        Ok(())
    }

    /// Build a descriptor from a parsed `[[device]]` TOML table. Fields:
    /// `id`, `name`, `cc` (string, e.g. "1.3"), `sms`, `sp_clock_mhz`,
    /// `mem_clock_mhz`, `mem_bus_bits`, `global_mem_mib`, and optional
    /// `mem_latency_cycles` / `row_switch_cycles` overrides.
    pub fn from_toml(t: &TomlTable) -> Result<DeviceDescriptor, String> {
        let get_str = |k: &str| -> Result<String, String> {
            t.get(k)
                .and_then(|v| v.as_str())
                .map(str::to_string)
                .ok_or_else(|| format!("device missing string field '{k}'"))
        };
        let get_int = |k: &str| -> Result<i64, String> {
            t.get(k)
                .and_then(|v| v.as_int())
                .ok_or_else(|| format!("device missing integer field '{k}'"))
        };
        let get_float = |k: &str| -> Result<f64, String> {
            t.get(k)
                .and_then(|v| v.as_float())
                .ok_or_else(|| format!("device missing float field '{k}'"))
        };
        let cc_str = get_str("cc")?;
        let cc = ComputeCapability::by_version(&cc_str)
            .ok_or_else(|| format!("unknown compute capability '{cc_str}'"))?;
        let d = DeviceDescriptor {
            id: get_str("id")?,
            name: get_str("name").unwrap_or_else(|_| get_str("id").unwrap()),
            cc,
            sm_count: get_int("sms")? as u32,
            sp_clock_mhz: get_float("sp_clock_mhz")?,
            mem_clock_mhz: get_float("mem_clock_mhz")?,
            mem_bus_bits: get_int("mem_bus_bits")? as u32,
            global_mem_mib: get_int("global_mem_mib")? as u32,
            mem_latency_cycles: t
                .get("mem_latency_cycles")
                .and_then(|v| v.as_float())
                .unwrap_or(500.0),
            row_switch_cycles: t
                .get("row_switch_cycles")
                .and_then(|v| v.as_float())
                .unwrap_or(20.0),
        };
        d.validate()?;
        Ok(d)
    }
}

impl fmt::Display for DeviceDescriptor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} ({}, {} SMs / {} SPs, {} MiB)",
            self.name,
            self.cc,
            self.sm_count,
            self.total_sps(),
            self.global_mem_mib
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::toml::TomlDoc;

    fn sample() -> DeviceDescriptor {
        DeviceDescriptor {
            id: "test".into(),
            name: "Test GPU".into(),
            cc: ComputeCapability::CC_1_3,
            sm_count: 24,
            sp_clock_mhz: 1242.0,
            mem_clock_mhz: 999.0 * 2.0,
            mem_bus_bits: 448,
            global_mem_mib: 896,
            mem_latency_cycles: 500.0,
            row_switch_cycles: 20.0,
        }
    }

    #[test]
    fn total_sps_matches_table1() {
        assert_eq!(sample().total_sps(), 192); // 24 SM × 8 SP
    }

    #[test]
    fn bandwidth_is_plausible() {
        // GTX 260: 448-bit @ ~2 GHz effective ≈ 104 GiB/s
        let bw = sample().mem_bandwidth_gib();
        assert!((90.0..120.0).contains(&bw), "bw={bw}");
    }

    #[test]
    fn validate_catches_bad_fields() {
        let mut d = sample();
        d.sm_count = 0;
        assert!(d.validate().is_err());
        let mut d = sample();
        d.sp_clock_mhz = -1.0;
        assert!(d.validate().is_err());
        let mut d = sample();
        d.id.clear();
        assert!(d.validate().is_err());
        assert!(sample().validate().is_ok());
    }

    #[test]
    fn from_toml_round_trip() {
        let doc = TomlDoc::parse(
            r#"
[[device]]
id = "mygpu"
name = "My GPU"
cc = "1.0"
sms = 12
sp_clock_mhz = 1188.0
mem_clock_mhz = 1584.0
mem_bus_bits = 320
global_mem_mib = 320
"#,
        )
        .unwrap();
        let d = DeviceDescriptor::from_toml(&doc.arrays["device"][0]).unwrap();
        assert_eq!(d.id, "mygpu");
        assert_eq!(d.cc.max_threads_per_sm, 768);
        assert_eq!(d.mem_latency_cycles, 500.0); // default applied
    }

    #[test]
    fn from_toml_rejects_unknown_cc() {
        let doc = TomlDoc::parse(
            "[[device]]\nid = \"x\"\nname = \"x\"\ncc = \"7.5\"\nsms = 1\nsp_clock_mhz = 1.0\nmem_clock_mhz = 1.0\nmem_bus_bits = 64\nglobal_mem_mib = 128\n",
        )
        .unwrap();
        assert!(DeviceDescriptor::from_toml(&doc.arrays["device"][0]).is_err());
    }
}
